//! Straggler attribution: per-wait last-arriver ledgers.
//!
//! Every rendezvous primitive in `comm` already knows who arrived
//! last — the generation barrier's releaser is by definition the
//! straggler, and a split-phase completion knows which source it
//! blocked on longest.  Each waiting rank accumulates those verdicts
//! into a [`Blame`] ledger indexed by the *blamed* (absolute) rank:
//! how many times it was waited for, and for how long in total.
//! Attribution is always on — it costs two clock reads per wait that
//! the comm layer already pays for its `sync_nanos` counters — and is
//! timing-only, so it cannot perturb the deterministic spike trains.

use crate::util::json::Json;

/// One waiting rank's ledger: `waits[b]` counts the rendezvous in
/// which rank `b` arrived last while this rank was already waiting,
/// and `lateness_secs[b]` sums the wait time attributed to it.
#[derive(Clone, Debug, Default)]
pub struct Blame {
    pub waits: Vec<u64>,
    pub lateness_secs: Vec<f64>,
}

impl Blame {
    /// An empty ledger over `m` blameable ranks.
    pub fn sized(m: usize) -> Blame {
        Blame { waits: vec![0; m], lateness_secs: vec![0.0; m] }
    }

    /// Record one wait: `blamed` arrived last, costing this rank
    /// `lateness_secs` of wall-clock wait.
    #[inline]
    pub fn record(&mut self, blamed: usize, lateness_secs: f64) {
        self.waits[blamed] += 1;
        self.lateness_secs[blamed] += lateness_secs.max(0.0);
    }

    /// Fold `other` into `self` (ledgers from sub-communicators use
    /// absolute rank indices, so folding is element-wise).
    pub fn merge(&mut self, other: &Blame) {
        if self.waits.len() < other.waits.len() {
            self.waits.resize(other.waits.len(), 0);
            self.lateness_secs.resize(other.lateness_secs.len(), 0.0);
        }
        for (b, &w) in other.waits.iter().enumerate() {
            self.waits[b] += w;
        }
        for (b, &l) in other.lateness_secs.iter().enumerate() {
            self.lateness_secs[b] += l;
        }
    }

    pub fn total_waits(&self) -> u64 {
        self.waits.iter().sum()
    }

    pub fn is_empty(&self) -> bool {
        self.total_waits() == 0
    }

    /// The most-blamed rank: `(rank, waits, lateness_secs)`, by wait
    /// count with lateness as tie-break.  `None` on an empty ledger.
    pub fn top(&self) -> Option<(usize, u64, f64)> {
        (0..self.waits.len())
            .filter(|&b| self.waits[b] > 0)
            .max_by(|&a, &b| {
                self.waits[a].cmp(&self.waits[b]).then(
                    self.lateness_secs[a].total_cmp(&self.lateness_secs[b]),
                )
            })
            .map(|b| (b, self.waits[b], self.lateness_secs[b]))
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            (
                "waits",
                Json::Arr(
                    self.waits.iter().map(|&w| Json::Num(w as f64)).collect(),
                ),
            ),
            ("lateness_secs", Json::nums(&self.lateness_secs)),
        ])
    }
}

/// Run-level attribution, per tier: `global[r]` / `local[r]` is the
/// ledger of waits *observed by* (absolute) rank `r` on that tier.
#[derive(Clone, Debug, Default)]
pub struct TieredBlame {
    pub global: Vec<Blame>,
    pub local: Vec<Blame>,
}

impl TieredBlame {
    pub fn sized(m: usize) -> TieredBlame {
        TieredBlame {
            global: vec![Blame::sized(m); m],
            local: vec![Blame::sized(m); m],
        }
    }

    /// Every wait of the run folded into one ledger — the summary's
    /// "who did the run wait for" view.
    pub fn merged_all(&self) -> Blame {
        let m = self.global.len().max(self.local.len());
        let mut all = Blame::sized(m);
        for b in self.global.iter().chain(self.local.iter()) {
            all.merge(b);
        }
        all
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn record_and_top() {
        let mut b = Blame::sized(4);
        assert!(b.is_empty());
        assert_eq!(b.top(), None);
        b.record(2, 0.5);
        b.record(2, 0.25);
        b.record(1, 3.0);
        assert_eq!(b.total_waits(), 3);
        let (rank, waits, late) = b.top().unwrap();
        assert_eq!((rank, waits), (2, 2));
        assert!((late - 0.75).abs() < 1e-12);
    }

    #[test]
    fn top_breaks_ties_by_lateness() {
        let mut b = Blame::sized(3);
        b.record(0, 1.0);
        b.record(2, 2.0);
        assert_eq!(b.top().unwrap().0, 2);
    }

    #[test]
    fn merge_is_elementwise_and_resizes() {
        let mut a = Blame::sized(2);
        a.record(1, 1.0);
        let mut b = Blame::sized(4);
        b.record(1, 2.0);
        b.record(3, 0.5);
        a.merge(&b);
        assert_eq!(a.waits, vec![0, 2, 0, 1]);
        assert!((a.lateness_secs[1] - 3.0).abs() < 1e-12);
    }

    #[test]
    fn negative_lateness_clamps_to_zero() {
        let mut b = Blame::sized(1);
        b.record(0, -1.0);
        assert_eq!(b.lateness_secs[0], 0.0);
        assert_eq!(b.waits[0], 1);
    }

    #[test]
    fn tiered_merge_all_spans_both_tiers() {
        let mut t = TieredBlame::sized(3);
        t.global[0].record(2, 1.0);
        t.local[1].record(2, 0.5);
        t.local[2].record(0, 0.1);
        let all = t.merged_all();
        assert_eq!(all.waits[2], 2);
        assert_eq!(all.waits[0], 1);
        assert_eq!(all.top().unwrap().0, 2);
    }

    #[test]
    fn json_shape() {
        let mut b = Blame::sized(2);
        b.record(0, 0.5);
        let j = b.to_json();
        assert_eq!(
            j.get("waits").unwrap().as_arr().unwrap()[0].as_u64(),
            Some(1)
        );
        assert_eq!(
            j.get("lateness_secs").unwrap().as_arr().unwrap().len(),
            2
        );
    }
}
