//! The machine-readable run report (`--stats-json`).
//!
//! One JSON document per run, schema-tagged `nsim-stats-v1`, holding
//! everything the paper's evaluation pipeline needs: the effective
//! configuration, per-rank phase breakdowns, tiered communication
//! statistics, per-rank interval distributions, the straggler ledger,
//! and the **model-vs-measurement closure**: the measured interval
//! mean/σ fitted into [`CycleTimeModel`] and the resulting predicted
//! `T_sync` per tier next to the measured synchronization wait —
//! the comparison that validates (or falsifies) the paper's
//! statistical sync model on every instrumented run.  When raw
//! per-cycle vectors were recorded (`--record-cycle-times`) the exact
//! lumped empirical sync time ([`empirical_sync_time`]) is included
//! too.
//!
//! Schema stability is tested by `tests/observability.rs`; bump the
//! `schema` tag when making breaking changes.

use super::intervals;
use crate::comm::CommStatsSnapshot;
use crate::config::RunConfig;
use crate::engine::SimResult;
use crate::theory::sync::{
    empirical_sync_time, expected_hybrid_sync_times, expected_sync_times,
    CycleTimeModel,
};
use crate::util::json::Json;
use crate::util::timers::{Phase, PhaseTimes};

/// Schema tag of the stats document.
pub const SCHEMA: &str = "nsim-stats-v1";

fn phase_times_json(t: &PhaseTimes) -> Json {
    Json::Obj(
        Phase::ALL
            .iter()
            .map(|&p| (p.name().to_string(), Json::Num(t.get(p))))
            .collect(),
    )
}

fn comm_snapshot_json(s: &CommStatsSnapshot) -> Json {
    Json::obj(vec![
        ("alltoall_calls", Json::Num(s.alltoall_calls as f64)),
        ("local_swaps", Json::Num(s.local_swaps as f64)),
        ("bytes_sent", Json::Num(s.bytes_sent as f64)),
        ("resize_rounds", Json::Num(s.resize_rounds as f64)),
        ("max_send_per_pair", Json::Num(s.max_send_per_pair as f64)),
        (
            "overlapped_exchanges",
            Json::Num(s.overlapped_exchanges as f64),
        ),
        (
            "early_drained_sources",
            Json::Num(s.early_drained_sources as f64),
        ),
        ("timeouts", Json::Num(s.timeouts as f64)),
        ("sync_secs", Json::Num(s.sync_secs)),
        ("post_secs", Json::Num(s.post_secs)),
        ("complete_wait_secs", Json::Num(s.complete_wait_secs)),
        ("hidden_secs", Json::Num(s.hidden_secs)),
    ])
}

/// Fit the measured per-cycle interval distribution (pooled across
/// ranks) into the paper's cycle-time model.  Returns `None` when no
/// intervals were recorded.
pub fn fitted_model(res: &SimResult) -> Option<CycleTimeModel> {
    let (n, mu, sigma) =
        intervals::pooled(res.intervals.iter().map(|t| &t.local));
    CycleTimeModel::from_measured(n, mu, sigma)
}

/// Predicted `(local, global)` sync time per rank over the whole run,
/// from the fitted model and the run's schedule shape.
pub fn predicted_sync(
    model: CycleTimeModel,
    cfg: &RunConfig,
    res: &SimResult,
) -> (f64, f64) {
    let d = res.epoch_cycles.max(1) as u32;
    if cfg.strategy.dual_pathways() && cfg.ranks_per_area > 1 {
        // hybrid two-tier schedule: the local tier rendezvous every
        // cycle (d rounds per epoch), the global tier once per epoch
        expected_hybrid_sync_times(
            model,
            res.m_ranks,
            cfg.ranks_per_area,
            res.s_cycles,
            d,
            d,
        )
    } else {
        let (conv, struc) =
            expected_sync_times(model, res.m_ranks, res.s_cycles, d);
        let global = if cfg.strategy.dual_pathways() { struc } else { conv };
        (0.0, global)
    }
}

/// The model-vs-measurement section: fitted cycle-time model,
/// predicted vs measured `T_sync` per tier, and (when raw cycle
/// vectors were recorded) the exact lumped empirical sync time.
fn sync_model_json(cfg: &RunConfig, res: &SimResult) -> Json {
    let d = res.epoch_cycles.max(1) as usize;
    let m = res.m_ranks.max(1) as f64;
    // measured per-rank average synchronization wait per tier: barrier
    // waits plus split-phase completion blocking (the stats atomics
    // accumulate across ranks, so divide by m)
    let meas_global = (res.comm_tiers.global.sync_secs
        + res.comm_tiers.global.complete_wait_secs)
        / m;
    let meas_local = (res.comm_tiers.local.sync_secs
        + res.comm_tiers.local.complete_wait_secs)
        / m;
    let empirical = {
        let rows = &res.cycle_times;
        let usable = !rows.is_empty()
            && rows.iter().all(|r| !r.is_empty())
            && rows.iter().all(|r| r.len() == rows[0].len());
        if usable {
            Json::Num(empirical_sync_time(rows, d))
        } else {
            Json::Null
        }
    };
    match fitted_model(res) {
        None => Json::obj(vec![
            ("fitted", Json::Null),
            ("empirical_lumped_secs", empirical),
        ]),
        Some(model) => {
            let (pred_local, pred_global) = predicted_sync(model, cfg, res);
            Json::obj(vec![
                (
                    "fitted",
                    Json::obj(vec![
                        ("mu_secs", Json::Num(model.mu)),
                        ("sigma_secs", Json::Num(model.sigma)),
                        ("cv", Json::Num(model.cv())),
                    ]),
                ),
                ("epoch_cycles", Json::Num(d as f64)),
                (
                    "tiers",
                    Json::obj(vec![
                        (
                            "global",
                            Json::obj(vec![
                                ("predicted_secs", Json::Num(pred_global)),
                                ("measured_secs", Json::Num(meas_global)),
                            ]),
                        ),
                        (
                            "local",
                            Json::obj(vec![
                                ("predicted_secs", Json::Num(pred_local)),
                                ("measured_secs", Json::Num(meas_local)),
                            ]),
                        ),
                    ]),
                ),
                ("empirical_lumped_secs", empirical),
            ])
        }
    }
}

fn stragglers_json(res: &SimResult) -> Json {
    let all = res.blame.merged_all();
    let top = match all.top() {
        Some((rank, waits, late)) => Json::obj(vec![
            ("rank", Json::Num(rank as f64)),
            ("waits", Json::Num(waits as f64)),
            ("lateness_secs", Json::Num(late)),
        ]),
        None => Json::Null,
    };
    Json::obj(vec![
        (
            "global",
            Json::Arr(res.blame.global.iter().map(|b| b.to_json()).collect()),
        ),
        (
            "local",
            Json::Arr(res.blame.local.iter().map(|b| b.to_json()).collect()),
        ),
        ("top", top),
    ])
}

/// Build the full stats document for one finished run.
pub fn run_report(model_name: &str, cfg: &RunConfig, res: &SimResult) -> Json {
    run_report_for_job(model_name, cfg, res, None)
}

/// [`run_report`] with an optional serving-layer job id stamped into
/// the config block (`config.job`, e.g. `"job-3"`).  Absent for direct
/// CLI runs — consumers treat the field as optional, mirroring
/// `config.transport`.
pub fn run_report_for_job(
    model_name: &str,
    cfg: &RunConfig,
    res: &SimResult,
    job: Option<&str>,
) -> Json {
    let mut config = vec![
        ("model", model_name.into()),
        ("strategy", cfg.strategy.name().into()),
        ("exec", cfg.exec.name().into()),
        ("comm", cfg.comm.name().into()),
        ("comm_depth", cfg.comm_depth.into()),
        ("transport", cfg.transport.name().into()),
        ("ranks_per_area", cfg.ranks_per_area.into()),
        ("m_ranks", cfg.m_ranks.into()),
        ("threads_per_rank", cfg.threads_per_rank.into()),
        ("t_model_ms", Json::Num(cfg.t_model_ms)),
        ("seed", Json::Num(cfg.seed as f64)),
        ("trace", cfg.trace.into()),
        ("record_cycle_times", cfg.record_cycle_times.into()),
    ];
    if let Some(id) = job {
        config.push(("job", id.into()));
    }
    Json::obj(vec![
        ("schema", SCHEMA.into()),
        ("config", Json::obj(config)),
        (
            "result",
            Json::obj(vec![
                ("s_cycles", Json::Num(res.s_cycles as f64)),
                ("epoch_cycles", Json::Num(res.epoch_cycles as f64)),
                ("rtf", Json::Num(res.rtf())),
                ("n_spikes", res.n_spikes().into()),
                (
                    "effective_comm_depth",
                    Json::Num(res.effective_comm_depth as f64),
                ),
            ]),
        ),
        (
            "phase_times",
            Json::obj(vec![
                (
                    "per_rank",
                    Json::Arr(
                        res.rank_times.iter().map(phase_times_json).collect(),
                    ),
                ),
                ("mean", phase_times_json(&res.mean_times)),
                ("max", phase_times_json(&res.max_times)),
            ]),
        ),
        (
            "comm",
            Json::obj(vec![
                ("global", comm_snapshot_json(&res.comm_tiers.global)),
                ("local", comm_snapshot_json(&res.comm_tiers.local)),
            ]),
        ),
        (
            "intervals",
            Json::Arr(res.intervals.iter().map(|t| t.to_json()).collect()),
        ),
        ("stragglers", stragglers_json(res)),
        ("sync_model", sync_model_json(cfg, res)),
    ])
}

/// Write the report to `path` (pretty-printed — reports are small and
/// meant to be read).
pub fn write_report(
    path: &std::path::Path,
    model_name: &str,
    cfg: &RunConfig,
    res: &SimResult,
) -> std::io::Result<()> {
    use std::io::Write;
    let doc = run_report(model_name, cfg, res);
    let mut f = std::fs::File::create(path)?;
    f.write_all(crate::util::json::to_string_pretty(&doc).as_bytes())?;
    f.write_all(b"\n")?;
    f.flush()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::blame::TieredBlame;
    use crate::obs::intervals::TierIntervals;

    fn tiny_result(m: usize) -> SimResult {
        let mut intervals = Vec::new();
        for _ in 0..m {
            let mut t = TierIntervals::new();
            for c in 0..8u64 {
                t.record_cycle(1.0e-3 + c as f64 * 1e-5, (c + 1) % 2 == 0);
            }
            intervals.push(t.summary());
        }
        let mut blame = TieredBlame::sized(m);
        blame.global[0].record(1, 0.5);
        SimResult {
            strategy: crate::config::Strategy::Conventional,
            m_ranks: m,
            rank_times: vec![PhaseTimes::new(); m],
            mean_times: PhaseTimes::new(),
            max_times: PhaseTimes::new(),
            spikes: Vec::new(),
            cycle_times: vec![Vec::new(); m],
            s_cycles: 8,
            t_model_ms: 1.0,
            rank_neurons: vec![1; m],
            rank_conns: vec![(0, 0); m],
            comm_stats: CommStatsSnapshot::default(),
            comm_tiers: Default::default(),
            effective_comm_depth: 1,
            ring_pending: vec![Vec::new(); m],
            epoch_cycles: 2,
            intervals,
            blame,
            spans: Vec::new(),
        }
    }

    #[test]
    fn report_has_all_sections_and_roundtrips() {
        let cfg = RunConfig { m_ranks: 2, ..Default::default() };
        let res = tiny_result(2);
        let doc = run_report("sanity", &cfg, &res);
        assert_eq!(doc.get("schema").unwrap().as_str(), Some(SCHEMA));
        for key in [
            "config",
            "result",
            "phase_times",
            "comm",
            "intervals",
            "stragglers",
            "sync_model",
        ] {
            assert!(doc.get(key).is_some(), "missing section {key}");
        }
        let transport =
            doc.get("config").unwrap().get("transport").unwrap();
        assert_eq!(transport.as_str(), Some("shmem"));
        let text = crate::util::json::to_string_pretty(&doc);
        let back = crate::util::json::parse(&text).unwrap();
        assert_eq!(back, doc);
    }

    #[test]
    fn job_field_only_present_for_server_jobs() {
        let cfg = RunConfig { m_ranks: 2, ..Default::default() };
        let res = tiny_result(2);
        // direct runs: no job key at all (schema-stable optionality)
        let doc = run_report("sanity", &cfg, &res);
        assert!(doc.get("config").unwrap().get("job").is_none());
        // server jobs: config.job carries the deterministic id
        let doc = run_report_for_job("sanity", &cfg, &res, Some("job-3"));
        assert_eq!(
            doc.get("config").unwrap().get("job").unwrap().as_str(),
            Some("job-3")
        );
        assert_eq!(doc.get("schema").unwrap().as_str(), Some(SCHEMA));
    }

    #[test]
    fn sync_model_fits_measured_intervals() {
        let cfg = RunConfig { m_ranks: 2, ..Default::default() };
        let res = tiny_result(2);
        let model = fitted_model(&res).unwrap();
        assert!(model.mu > 1.0e-3 && model.mu < 1.2e-3);
        let doc = run_report("sanity", &cfg, &res);
        let fitted = doc.get("sync_model").unwrap().get("fitted").unwrap();
        assert!(fitted.get("mu_secs").unwrap().as_f64().unwrap() > 0.0);
        let tiers = doc.get("sync_model").unwrap().get("tiers").unwrap();
        for tier in ["global", "local"] {
            let t = tiers.get(tier).unwrap();
            assert!(t.get("predicted_secs").unwrap().as_f64().is_some());
            assert!(t.get("measured_secs").unwrap().as_f64().is_some());
        }
        // no raw cycle vectors recorded -> exact empirical is null
        assert_eq!(
            doc.get("sync_model").unwrap().get("empirical_lumped_secs"),
            Some(&Json::Null)
        );
    }

    #[test]
    fn empirical_section_present_with_recorded_cycles() {
        let cfg = RunConfig { m_ranks: 2, ..Default::default() };
        let mut res = tiny_result(2);
        res.cycle_times =
            vec![vec![1.0e-3; 8], vec![1.1e-3; 8]];
        let doc = run_report("sanity", &cfg, &res);
        let emp = doc
            .get("sync_model")
            .unwrap()
            .get("empirical_lumped_secs")
            .unwrap();
        assert!(emp.as_f64().unwrap() > 0.0);
    }

    #[test]
    fn straggler_top_names_blamed_rank() {
        let cfg = RunConfig { m_ranks: 2, ..Default::default() };
        let res = tiny_result(2);
        let doc = run_report("sanity", &cfg, &res);
        let top = doc.get("stragglers").unwrap().get("top").unwrap();
        assert_eq!(top.get("rank").unwrap().as_u64(), Some(1));
    }

    #[test]
    fn predicted_sync_hybrid_vs_flat() {
        let model = CycleTimeModel::paper_default();
        let mut cfg = RunConfig {
            m_ranks: 4,
            strategy: crate::config::Strategy::StructureAware,
            ranks_per_area: 2,
            ..Default::default()
        };
        let mut res = tiny_result(4);
        res.m_ranks = 4;
        res.epoch_cycles = 2;
        let (local, global) = predicted_sync(model, &cfg, &res);
        assert!(local > 0.0 && global > 0.0);
        cfg.ranks_per_area = 1;
        let (l2, g2) = predicted_sync(model, &cfg, &res);
        assert_eq!(l2, 0.0);
        assert!(g2 > 0.0);
    }
}
