//! Streaming interval distributions.
//!
//! The paper characterizes a run by the *distribution* of compute
//! intervals between communication calls — mean, CV and tail shape —
//! because the expected synchronization cost is an order statistic of
//! that distribution (`theory::sync`).  [`IntervalRecorder`] captures
//! it per rank in constant memory: a Welford moment accumulator
//! ([`crate::util::stats::Moments`]) next to a fixed 64-bin log₂
//! histogram, so a billion-cycle run costs the same few hundred bytes
//! as a ten-cycle one.  This replaces the unbounded
//! `record_cycle_times` vectors as the default (the raw vectors stay
//! available behind `--record-cycle-times` for exact lumping).
//!
//! [`TierIntervals`] tracks both tiers of the hierarchical schedule:
//! the **local** interval is one cycle of compute (the local-tier
//! alltoall rendezvous every cycle), the **global** interval is the
//! epoch accumulation between global exchanges (`d` lumped cycles
//! under the structure-aware strategy — the paper's CLT lumping made
//! measurable).

use crate::util::json::Json;
use crate::util::stats::{
    log2_bin, log2_bin_lo, log2_hist_quantile, Moments, LOG2_HIST_BINS,
};

/// Constant-memory distribution sketch of one interval stream.
#[derive(Clone, Debug)]
pub struct IntervalRecorder {
    moments: Moments,
    hist: [u64; LOG2_HIST_BINS],
}

impl Default for IntervalRecorder {
    fn default() -> Self {
        Self::new()
    }
}

impl IntervalRecorder {
    pub fn new() -> IntervalRecorder {
        IntervalRecorder { moments: Moments::new(), hist: [0; LOG2_HIST_BINS] }
    }

    /// Record one interval (seconds).
    #[inline]
    pub fn push(&mut self, secs: f64) {
        self.moments.push(secs);
        self.hist[log2_bin(secs)] += 1;
    }

    pub fn n(&self) -> u64 {
        self.moments.n()
    }

    pub fn summary(&self) -> IntervalSummary {
        IntervalSummary {
            n: self.moments.n(),
            mean: self.moments.mean(),
            std_dev: self.moments.std_dev(),
            cv: self.moments.cv(),
            min: self.moments.min(),
            max: self.moments.max(),
            p50: log2_hist_quantile(&self.hist, 0.50),
            p90: log2_hist_quantile(&self.hist, 0.90),
            p99: log2_hist_quantile(&self.hist, 0.99),
            hist: (0..LOG2_HIST_BINS)
                .filter(|&i| self.hist[i] > 0)
                .map(|i| (log2_bin_lo(i), self.hist[i]))
                .collect(),
        }
    }
}

/// Plain-data summary of one interval stream: exact moments plus
/// histogram-derived quantiles (each within a ×√2 bin of truth) and
/// the non-empty histogram bins as `(lower_edge_secs, count)`.
#[derive(Clone, Debug, Default)]
pub struct IntervalSummary {
    pub n: u64,
    pub mean: f64,
    pub std_dev: f64,
    pub cv: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
    pub p90: f64,
    pub p99: f64,
    pub hist: Vec<(f64, u64)>,
}

impl IntervalSummary {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("n", Json::Num(self.n as f64)),
            ("mean_secs", Json::Num(self.mean)),
            ("std_dev_secs", Json::Num(self.std_dev)),
            ("cv", Json::Num(self.cv)),
            ("min_secs", Json::Num(self.min)),
            ("max_secs", Json::Num(self.max)),
            ("p50_secs", Json::Num(self.p50)),
            ("p90_secs", Json::Num(self.p90)),
            ("p99_secs", Json::Num(self.p99)),
            (
                "hist",
                Json::Arr(
                    self.hist
                        .iter()
                        .map(|&(lo, c)| {
                            Json::Arr(vec![
                                Json::Num(lo),
                                Json::Num(c as f64),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

/// Pool per-rank summaries into run-level `(n, mean, std_dev)` via the
/// parallel moment-merge identity (Chan et al.) — the population the
/// statistical sync model is fitted on.
pub fn pooled<'a, I>(summaries: I) -> (u64, f64, f64)
where
    I: IntoIterator<Item = &'a IntervalSummary>,
{
    let (mut n, mut mean, mut m2) = (0u64, 0.0f64, 0.0f64);
    for s in summaries {
        if s.n == 0 {
            continue;
        }
        let (nb, mb) = (s.n as f64, s.mean);
        let m2b = s.std_dev * s.std_dev * nb;
        let na = n as f64;
        let delta = mb - mean;
        let nt = na + nb;
        mean += delta * nb / nt;
        m2 += m2b + delta * delta * na * nb / nt;
        n += s.n;
    }
    if n == 0 {
        (0, 0.0, 0.0)
    } else {
        (n, mean, (m2 / n as f64).max(0.0).sqrt())
    }
}

/// Both tiers' interval streams for one rank.
#[derive(Clone, Debug, Default)]
pub struct TierIntervals {
    local: IntervalRecorder,
    global: IntervalRecorder,
    epoch_accum: f64,
}

impl TierIntervals {
    pub fn new() -> TierIntervals {
        TierIntervals::default()
    }

    /// Record one cycle's compute time; at an epoch boundary the
    /// accumulated epoch flushes into the global-tier stream.
    #[inline]
    pub fn record_cycle(&mut self, secs: f64, epoch_boundary: bool) {
        self.local.push(secs);
        self.epoch_accum += secs;
        if epoch_boundary {
            self.global.push(self.epoch_accum);
            self.epoch_accum = 0.0;
        }
    }

    pub fn summary(&self) -> TierIntervalSummary {
        TierIntervalSummary {
            local: self.local.summary(),
            global: self.global.summary(),
        }
    }
}

/// Per-rank summary of both tiers.
#[derive(Clone, Debug, Default)]
pub struct TierIntervalSummary {
    /// Per-cycle compute intervals (the local-tier rendezvous grain).
    pub local: IntervalSummary,
    /// Per-epoch accumulated intervals (the global-exchange grain).
    pub global: IntervalSummary,
}

impl TierIntervalSummary {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("local", self.local.to_json()),
            ("global", self.global.to_json()),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;
    use crate::util::stats;

    #[test]
    fn summary_matches_batch_statistics() {
        let mut r = Pcg64::seed_from_u64(11);
        let xs: Vec<f64> =
            (0..4000).map(|_| r.normal_ms(1.6e-3, 0.09e-3).max(1e-6)).collect();
        let mut rec = IntervalRecorder::new();
        for &x in &xs {
            rec.push(x);
        }
        let s = rec.summary();
        assert_eq!(s.n, xs.len() as u64);
        assert!((s.mean - stats::mean(&xs)).abs() < 1e-12);
        assert!((s.std_dev - stats::std_dev(&xs)).abs() < 1e-9);
        assert!((s.cv - stats::cv(&xs)).abs() < 1e-6);
        // histogram quantiles land within a sqrt(2) bin of the truth
        let p50 = stats::quantile(&xs, 0.5);
        assert!(s.p50 >= p50 / 2.0_f64.sqrt() && s.p50 <= p50 * 2.0_f64.sqrt());
        let total: u64 = s.hist.iter().map(|&(_, c)| c).sum();
        assert_eq!(total, s.n);
    }

    #[test]
    fn tier_split_respects_epoch_boundaries() {
        let mut t = TierIntervals::new();
        let d = 4usize;
        for cycle in 0..20usize {
            t.record_cycle(1.0, (cycle + 1) % d == 0);
        }
        let s = t.summary();
        assert_eq!(s.local.n, 20);
        assert_eq!(s.global.n, 5);
        assert!((s.local.mean - 1.0).abs() < 1e-12);
        assert!((s.global.mean - 4.0).abs() < 1e-12);
        assert_eq!(s.global.std_dev, 0.0);
    }

    #[test]
    fn partial_trailing_epoch_is_not_flushed() {
        let mut t = TierIntervals::new();
        t.record_cycle(1.0, false);
        t.record_cycle(1.0, true);
        t.record_cycle(1.0, false); // trailing partial epoch
        let s = t.summary();
        assert_eq!(s.local.n, 3);
        assert_eq!(s.global.n, 1);
    }

    #[test]
    fn pooled_equals_single_population() {
        let mut r = Pcg64::seed_from_u64(3);
        let xs: Vec<f64> =
            (0..3000).map(|_| r.normal_ms(2.0, 0.5).abs() + 1e-9).collect();
        // split across 3 "ranks"
        let mut recs = vec![IntervalRecorder::new(); 3];
        for (i, &x) in xs.iter().enumerate() {
            recs[i % 3].push(x);
        }
        let summaries: Vec<IntervalSummary> =
            recs.iter().map(|r| r.summary()).collect();
        let (n, mean, sd) = pooled(summaries.iter());
        assert_eq!(n, xs.len() as u64);
        assert!((mean - stats::mean(&xs)).abs() < 1e-9);
        assert!((sd - stats::std_dev(&xs)).abs() < 1e-9);
    }

    #[test]
    fn pooled_of_empty_is_zero() {
        let (n, mean, sd) = pooled(std::iter::empty());
        assert_eq!((n, mean, sd), (0, 0.0, 0.0));
        let empty = IntervalSummary::default();
        let (n2, ..) = pooled(std::iter::once(&empty));
        assert_eq!(n2, 0);
    }

    #[test]
    fn json_shape() {
        let mut rec = IntervalRecorder::new();
        rec.push(1e-3);
        rec.push(2e-3);
        let j = rec.summary().to_json();
        assert_eq!(j.get("n").unwrap().as_u64(), Some(2));
        assert!(j.get("mean_secs").unwrap().as_f64().unwrap() > 0.0);
        assert!(!j.get("hist").unwrap().as_arr().unwrap().is_empty());
    }
}
