//! Chrome-trace-event export.
//!
//! Spans are emitted as complete events (`"ph": "X"`) in the [Trace
//! Event Format] consumed by Perfetto (`ui.perfetto.dev`) and
//! `chrome://tracing`: `pid` is the rank, `tid` the lane within the
//! rank (the instrumented operations all run on the rank coordinator,
//! lane 0), `ts`/`dur` are µs since the run origin (fractional values
//! carry sub-µs precision), `cat` is the communicator tier, and the
//! schedule attribution (epoch / cycle / ring slot / blamed peer)
//! rides in `args`.  Metadata events name each rank's process row so
//! the timeline reads "rank 0, rank 1, …" instead of bare pids.
//!
//! [Trace Event Format]: https://docs.google.com/document/d/1CvAClvFfyA5R-PhYUmn5OOQtYMH4h6I0nSsKchNAySU

use super::SpanEvent;
use crate::util::json::{self, Json};
use std::io::Write;
use std::path::Path;

/// Build the trace document: `{"traceEvents": [...], ...}`.
pub fn trace_json(spans: &[SpanEvent], m_ranks: usize) -> Json {
    let mut events = Vec::with_capacity(spans.len() + m_ranks);
    for pid in 0..m_ranks {
        events.push(Json::obj(vec![
            ("ph", "M".into()),
            ("name", "process_name".into()),
            ("pid", pid.into()),
            ("tid", 0usize.into()),
            (
                "args",
                Json::obj(vec![("name", Json::Str(format!("rank {pid}")))]),
            ),
        ]));
    }
    for s in spans {
        let mut args = Vec::new();
        if s.ctx.epoch >= 0 {
            args.push(("epoch", Json::Num(s.ctx.epoch as f64)));
        }
        if s.ctx.cycle >= 0 {
            args.push(("cycle", Json::Num(s.ctx.cycle as f64)));
        }
        if s.ctx.slot >= 0 {
            args.push(("ring_slot", Json::Num(s.ctx.slot as f64)));
        }
        if s.ctx.src >= 0 {
            args.push(("src", Json::Num(s.ctx.src as f64)));
        }
        let mut ev = vec![
            ("ph", "X".into()),
            ("name", s.name.into()),
            ("cat", s.ctx.tier.name().into()),
            ("pid", Json::Num(s.pid as f64)),
            ("tid", Json::Num(s.tid as f64)),
            ("ts", Json::Num(s.ts_us)),
            ("dur", Json::Num(s.dur_us)),
        ];
        if !args.is_empty() {
            ev.push(("args", Json::obj(args)));
        }
        events.push(Json::obj(ev));
    }
    Json::obj(vec![
        ("traceEvents", Json::Arr(events)),
        ("displayTimeUnit", "ms".into()),
    ])
}

/// Write the trace document to `path` (compact JSON — traces are big).
pub fn write_chrome_trace(
    path: &Path,
    spans: &[SpanEvent],
    m_ranks: usize,
) -> std::io::Result<()> {
    let doc = trace_json(spans, m_ranks);
    let mut f = std::fs::File::create(path)?;
    f.write_all(json::to_string(&doc).as_bytes())?;
    f.write_all(b"\n")?;
    f.flush()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::{SpanCtx, Tier};

    fn span(
        name: &'static str,
        pid: u32,
        ts: f64,
        dur: f64,
        ctx: SpanCtx,
    ) -> SpanEvent {
        SpanEvent { name, pid, tid: 0, ts_us: ts, dur_us: dur, ctx }
    }

    #[test]
    fn document_shape_and_metadata() {
        let spans = vec![
            span("update", 0, 10.0, 5.0, SpanCtx::cycle(3)),
            span(
                "post",
                1,
                12.5,
                0.25,
                SpanCtx {
                    tier: Tier::Global,
                    epoch: 2,
                    slot: 1,
                    ..SpanCtx::NONE
                },
            ),
        ];
        let doc = trace_json(&spans, 2);
        let evs = doc.get("traceEvents").unwrap().as_arr().unwrap();
        // 2 metadata + 2 spans
        assert_eq!(evs.len(), 4);
        assert_eq!(evs[0].get("ph").unwrap().as_str(), Some("M"));
        assert_eq!(
            evs[0].get("args").unwrap().get("name").unwrap().as_str(),
            Some("rank 0")
        );
        let upd = &evs[2];
        assert_eq!(upd.get("ph").unwrap().as_str(), Some("X"));
        assert_eq!(upd.get("name").unwrap().as_str(), Some("update"));
        assert_eq!(
            upd.get("args").unwrap().get("cycle").unwrap().as_u64(),
            Some(3)
        );
        assert!(upd.get("args").unwrap().get("epoch").is_none());
        let post = &evs[3];
        assert_eq!(post.get("cat").unwrap().as_str(), Some("global"));
        assert_eq!(
            post.get("args").unwrap().get("ring_slot").unwrap().as_u64(),
            Some(1)
        );
        assert_eq!(post.get("ts").unwrap().as_f64(), Some(12.5));
    }

    #[test]
    fn roundtrips_through_parser() {
        let spans =
            vec![span("barrier", 3, 0.125, 1.5, SpanCtx::tier(Tier::Local))];
        let doc = trace_json(&spans, 4);
        let text = json::to_string(&doc);
        let back = json::parse(&text).unwrap();
        assert_eq!(back, doc);
    }

    #[test]
    fn write_and_reload_file() {
        let dir = std::env::temp_dir().join("nsim_obs_trace_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.json");
        let spans = vec![span("deliver", 0, 1.0, 2.0, SpanCtx::cycle(0))];
        write_chrome_trace(&path, &spans, 1).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let doc = json::parse(text.trim()).unwrap();
        assert_eq!(
            doc.get("traceEvents").unwrap().as_arr().unwrap().len(),
            2
        );
        std::fs::remove_file(&path).ok();
    }
}
