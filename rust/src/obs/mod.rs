//! Observability: event tracing, interval distributions, straggler
//! attribution and machine-readable run reports.
//!
//! The paper's core result was produced by *profiling*: measuring the
//! distribution of compute times between communication calls shows that
//! the bottleneck is the wait for the slowest rank, not the collective
//! itself.  This module gives the functional engine the same
//! methodology, in four layers:
//!
//! 1. **event tracing** — a per-rank span recorder ([`Tracer`] writing
//!    into a shared [`TraceBuf`]) instruments the phase steps of
//!    `engine::rank` and every communication operation of `comm`
//!    (barrier waits, split-phase post/drain/complete/abandon,
//!    local-tier alltoalls, checkpoint writes).  Each span carries a
//!    [`SpanCtx`] attributing it to rank / tier / epoch / cycle /
//!    ring-slot / peer, and [`trace`] exports the whole run as a
//!    Chrome-trace-event JSON timeline loadable in Perfetto;
//! 2. **interval distributions** — [`intervals`] streams per-rank
//!    histograms, CV and quantiles of the compute intervals between
//!    communication calls, per tier, in constant memory (replacing the
//!    unbounded `record_cycle_times` vectors as the default);
//! 3. **straggler attribution** — the rendezvous primitives already
//!    know who arrived last; [`blame`] accumulates per-wait
//!    last-arriver and lateness into a per-rank ledger;
//! 4. **run report** — [`report`] emits the machine-readable
//!    `--stats-json` document and closes the loop on the paper's
//!    statistical model by fitting the measured interval mean/σ into
//!    [`crate::theory::sync::CycleTimeModel`] and comparing predicted
//!    against measured `T_sync` per tier.
//!
//! **Determinism.**  Tracing and attribution are timing-only: they
//! read clocks and append to pre-sized buffers but never touch spike
//! payloads, RNG state or the communication schedule, so spike trains
//! are bit-identical with observability on or off (enforced by
//! `tests/equivalence.rs`).  When tracing is off ([`Tracer::off`]) the
//! record sites reduce to one branch on an `Option` — no clock reads,
//! no locks — which is what the hot-path bench's A/B pair gates.

pub mod blame;
pub mod intervals;
pub mod report;
pub mod trace;

use std::sync::{Arc, Mutex};
use std::time::Instant;

/// Communicator tier an event belongs to.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Tier {
    /// Not a communicator event (compute phases, checkpoint writes).
    None,
    /// Intra-area-group communicator (`Transport::split` child).
    Local,
    /// The root inter-area communicator.
    Global,
}

impl Tier {
    pub fn name(self) -> &'static str {
        match self {
            Tier::None => "none",
            Tier::Local => "local",
            Tier::Global => "global",
        }
    }

    /// Map the comm layer's `&'static str` tier tag.
    pub fn from_tier_str(s: &str) -> Tier {
        match s {
            "local" => Tier::Local,
            "global" => Tier::Global,
            _ => Tier::None,
        }
    }
}

/// Attribution attached to a span: where in the simulation schedule the
/// event happened.  Negative values mean "not applicable" and are
/// omitted from the exported trace.
#[derive(Clone, Copy, Debug)]
pub struct SpanCtx {
    pub tier: Tier,
    /// Exchange epoch (the split-phase sequence number).
    pub epoch: i64,
    /// Simulation cycle.
    pub cycle: i64,
    /// Ring slot of a split-phase exchange (`epoch % 2·depth`).
    pub slot: i32,
    /// Peer rank the event is attributed to (last arriver of a wait,
    /// the source a completion blocked on).
    pub src: i32,
}

impl SpanCtx {
    pub const NONE: SpanCtx =
        SpanCtx { tier: Tier::None, epoch: -1, cycle: -1, slot: -1, src: -1 };

    /// A span attributed only to a tier.
    pub fn tier(tier: Tier) -> SpanCtx {
        SpanCtx { tier, ..SpanCtx::NONE }
    }

    /// A compute-phase span attributed to a cycle.
    pub fn cycle(cycle: u64) -> SpanCtx {
        SpanCtx { cycle: cycle as i64, ..SpanCtx::NONE }
    }
}

/// One completed span, in the Chrome trace-event model: a named
/// interval `[ts_us, ts_us + dur_us)` on timeline `(pid, tid)` where
/// `pid` is the (absolute) rank and `tid` the lane within the rank.
/// Timestamps are µs since the run's shared origin; fractional values
/// carry sub-µs precision.
#[derive(Clone, Copy, Debug)]
pub struct SpanEvent {
    pub name: &'static str,
    pub pid: u32,
    pub tid: u32,
    pub ts_us: f64,
    pub dur_us: f64,
    pub ctx: SpanCtx,
}

/// Bounding mode of the trace buffer.
///
/// One-shot CLI runs default to [`TraceMode::Unbounded`] — the sink
/// grows past its pre-allocated capacity if the run is long, and
/// nothing is lost.  Long-running processes (the job server tracing
/// for days) use [`TraceMode::Ring`]: each rank sink keeps only its
/// most recent N spans, evicting oldest-first, so memory is bounded by
/// `m_ranks × N` spans no matter how long the process lives.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum TraceMode {
    /// Grow without bound (one-shot runs; nothing evicted).
    #[default]
    Unbounded,
    /// Keep only the most recent N spans per rank sink.
    Ring(usize),
}

/// Pre-allocated spans per sink — growth beyond this doubles the `Vec`
/// in unbounded mode (rare, amortized O(1); steady state allocates
/// nothing) and is the default ring capacity of `--trace-mode ring`.
pub const SINK_CAPACITY: usize = 1 << 14;

/// One rank's span sink.  In ring mode `events` acts as a circular
/// buffer once it reaches capacity: `next` is the oldest retained
/// span's index (= the next overwrite position); in unbounded mode
/// `next` stays 0 and `events` is a plain append log.
struct Sink {
    events: Vec<SpanEvent>,
    next: usize,
}

/// The shared per-run trace buffer: one pre-allocated sink per rank,
/// all stamped against a single [`Instant`] origin so cross-rank spans
/// align on one timeline.  A rank only ever pushes into its own sink
/// (every instrumented operation runs on the rank's coordinator
/// thread), so the per-sink mutex is uncontended; it exists so
/// [`TraceBuf::drain`] at run end is safe without `unsafe`.
pub struct TraceBuf {
    origin: Instant,
    mode: TraceMode,
    sinks: Vec<Mutex<Sink>>,
}

impl TraceBuf {
    /// Pre-allocated spans per sink (see the module-level
    /// [`SINK_CAPACITY`]).
    pub const SINK_CAPACITY: usize = SINK_CAPACITY;

    pub fn new(m_ranks: usize) -> Arc<TraceBuf> {
        Self::with_mode(m_ranks, TraceMode::Unbounded)
    }

    /// A trace buffer with an explicit bounding mode
    /// (`--trace-mode`).
    pub fn with_mode(m_ranks: usize, mode: TraceMode) -> Arc<TraceBuf> {
        // ring capacities can be huge ("bound me at a million spans");
        // pre-allocate at most the standard sink size and let the ring
        // grow toward its cap on demand
        let prealloc = match mode {
            TraceMode::Unbounded => SINK_CAPACITY,
            TraceMode::Ring(cap) => cap.max(1).min(SINK_CAPACITY),
        };
        Arc::new(TraceBuf {
            origin: Instant::now(),
            mode,
            sinks: (0..m_ranks)
                .map(|_| {
                    Mutex::new(Sink {
                        events: Vec::with_capacity(prealloc),
                        next: 0,
                    })
                })
                .collect(),
        })
    }

    pub fn m_ranks(&self) -> usize {
        self.sinks.len()
    }

    /// µs since the run origin.
    #[inline]
    pub fn now_us(&self) -> f64 {
        self.origin.elapsed().as_secs_f64() * 1e6
    }

    #[inline]
    pub fn push(&self, sink: usize, ev: SpanEvent) {
        let mut s = self.sinks[sink].lock().unwrap();
        match self.mode {
            TraceMode::Unbounded => s.events.push(ev),
            TraceMode::Ring(cap) => {
                let cap = cap.max(1);
                if s.events.len() < cap {
                    s.events.push(ev);
                } else {
                    // full: overwrite the oldest retained span
                    let i = s.next;
                    s.events[i] = ev;
                    s.next = (i + 1) % cap;
                }
            }
        }
    }

    /// Drain every sink into one list ordered by
    /// `(pid, tid, start, -duration)` so enclosing spans precede the
    /// spans they contain.  Wrapped ring sinks are rotated
    /// oldest-first before the global sort, so the result is a
    /// well-formed (suffix of a) timeline either way.
    pub fn drain(&self) -> Vec<SpanEvent> {
        let mut out = Vec::new();
        for s in &self.sinks {
            let mut sink = s.lock().unwrap();
            let next = std::mem::take(&mut sink.next);
            let mut evs = std::mem::take(&mut sink.events);
            if next > 0 {
                // the ring wrapped: [next..] holds the oldest spans
                evs.rotate_left(next);
            }
            out.append(&mut evs);
        }
        out.sort_by(|a, b| {
            (a.pid, a.tid)
                .cmp(&(b.pid, b.tid))
                .then(a.ts_us.total_cmp(&b.ts_us))
                .then(b.dur_us.total_cmp(&a.dur_us))
        });
        out
    }
}

/// A rank's recording handle.  [`Tracer::off`] is the disabled state:
/// [`Tracer::start`] skips the clock read and [`Tracer::span`] is a
/// no-op, so an instrumented site costs one `Option` branch when
/// tracing is not requested.
#[derive(Clone)]
pub struct Tracer {
    buf: Option<Arc<TraceBuf>>,
    pid: u32,
    sink: usize,
}

impl Tracer {
    pub fn off() -> Tracer {
        Tracer { buf: None, pid: 0, sink: 0 }
    }

    /// Recording handle for (absolute) `rank`.
    pub fn new(buf: &Arc<TraceBuf>, rank: usize) -> Tracer {
        assert!(rank < buf.m_ranks());
        Tracer { buf: Some(Arc::clone(buf)), pid: rank as u32, sink: rank }
    }

    #[inline]
    pub fn enabled(&self) -> bool {
        self.buf.is_some()
    }

    /// Start timestamp for a span-to-be; `0.0` (never observed) when
    /// disabled.
    #[inline]
    pub fn start(&self) -> f64 {
        match &self.buf {
            Some(b) => b.now_us(),
            None => 0.0,
        }
    }

    /// Close a span opened at `start_us` (from [`Tracer::start`]).
    #[inline]
    pub fn span(&self, name: &'static str, start_us: f64, ctx: SpanCtx) {
        if let Some(b) = &self.buf {
            let now = b.now_us();
            b.push(
                self.sink,
                SpanEvent {
                    name,
                    pid: self.pid,
                    tid: 0,
                    ts_us: start_us,
                    dur_us: (now - start_us).max(0.0),
                    ctx,
                },
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_tracer_records_nothing() {
        let t = Tracer::off();
        assert!(!t.enabled());
        assert_eq!(t.start(), 0.0);
        t.span("noop", 0.0, SpanCtx::NONE); // must not panic
    }

    #[test]
    fn spans_drain_sorted_with_parents_first() {
        let buf = TraceBuf::new(2);
        let t0 = Tracer::new(&buf, 0);
        let t1 = Tracer::new(&buf, 1);
        assert!(t0.enabled());
        // child pushed before parent, parent starts earlier & lasts
        // longer — drain must order parent before child on rank 0
        buf.push(
            0,
            SpanEvent {
                name: "child",
                pid: 0,
                tid: 0,
                ts_us: 5.0,
                dur_us: 2.0,
                ctx: SpanCtx::NONE,
            },
        );
        buf.push(
            0,
            SpanEvent {
                name: "parent",
                pid: 0,
                tid: 0,
                ts_us: 5.0,
                dur_us: 10.0,
                ctx: SpanCtx::NONE,
            },
        );
        let s1 = t1.start();
        t1.span("real", s1, SpanCtx::tier(Tier::Global));
        let spans = buf.drain();
        assert_eq!(spans.len(), 3);
        assert_eq!(spans[0].name, "parent");
        assert_eq!(spans[1].name, "child");
        assert_eq!(spans[2].name, "real");
        assert_eq!(spans[2].pid, 1);
        assert!(spans[2].dur_us >= 0.0);
        // drained: second drain is empty
        assert!(buf.drain().is_empty());
    }

    #[test]
    fn tracer_span_measures_monotonic_time() {
        let buf = TraceBuf::new(1);
        let t = Tracer::new(&buf, 0);
        let s = t.start();
        std::thread::sleep(std::time::Duration::from_millis(2));
        t.span("sleep", s, SpanCtx::cycle(7));
        let spans = buf.drain();
        assert_eq!(spans.len(), 1);
        assert!(spans[0].dur_us >= 1000.0, "dur {}", spans[0].dur_us);
        assert_eq!(spans[0].ctx.cycle, 7);
    }

    fn ev(ts: f64, cycle: u64) -> SpanEvent {
        SpanEvent {
            name: "seg",
            pid: 0,
            tid: 0,
            ts_us: ts,
            dur_us: 1.0,
            ctx: SpanCtx::cycle(cycle),
        }
    }

    #[test]
    fn ring_mode_evicts_oldest_first() {
        let buf = TraceBuf::with_mode(1, TraceMode::Ring(4));
        for i in 0..10u64 {
            buf.push(0, ev(i as f64, i));
        }
        let spans = buf.drain();
        // only the newest 4 survive, oldest-first
        assert_eq!(spans.len(), 4);
        let cycles: Vec<u64> = spans.iter().map(|s| s.ctx.cycle).collect();
        assert_eq!(cycles, vec![6, 7, 8, 9]);
        assert!(buf.drain().is_empty());
    }

    #[test]
    fn ring_mode_below_capacity_keeps_everything() {
        let buf = TraceBuf::with_mode(1, TraceMode::Ring(8));
        for i in 0..5u64 {
            buf.push(0, ev(i as f64, i));
        }
        let spans = buf.drain();
        assert_eq!(spans.len(), 5);
        assert_eq!(spans[0].ctx.cycle, 0);
        assert_eq!(spans[4].ctx.cycle, 4);
    }

    #[test]
    fn wrapped_ring_exports_well_formed_chrome_trace() {
        let buf = TraceBuf::with_mode(2, TraceMode::Ring(3));
        // wrap rank 0 twice over; leave rank 1 un-wrapped
        for i in 0..8u64 {
            buf.push(0, ev(i as f64, i));
        }
        buf.push(
            1,
            SpanEvent {
                name: "seg",
                pid: 1,
                tid: 0,
                ts_us: 2.5,
                dur_us: 0.5,
                ctx: SpanCtx::cycle(100),
            },
        );
        let spans = buf.drain();
        assert_eq!(spans.len(), 4);
        let json = trace::trace_json(&spans, 2);
        let evs = json
            .get("traceEvents")
            .and_then(|v| v.as_arr())
            .expect("traceEvents array");
        // m_ranks metadata events + one X event per retained span
        assert_eq!(evs.len(), 2 + spans.len());
        let mut last_ts: std::collections::BTreeMap<u64, f64> =
            std::collections::BTreeMap::new();
        let mut x_events = 0;
        for e in evs {
            let ph = e.get("ph").and_then(|v| v.as_str()).expect("ph");
            if ph == "M" {
                continue;
            }
            assert_eq!(ph, "X");
            x_events += 1;
            assert!(e.get("name").and_then(|v| v.as_str()).is_some());
            let pid = e.get("pid").and_then(|v| v.as_u64()).expect("pid");
            let ts = e.get("ts").and_then(|v| v.as_f64()).expect("ts");
            assert!(e.get("dur").and_then(|v| v.as_f64()).expect("dur") >= 0.0);
            // per-rank timestamps stay monotonic after the wrap
            if let Some(prev) = last_ts.insert(pid, ts) {
                assert!(ts >= prev, "pid {pid}: ts {ts} < prev {prev}");
            }
        }
        assert_eq!(x_events, spans.len());
        // wrap kept the newest rank-0 spans in timeline order
        let r0: Vec<u64> = spans
            .iter()
            .filter(|s| s.pid == 0)
            .map(|s| s.ctx.cycle)
            .collect();
        assert_eq!(r0, vec![5, 6, 7]);
    }

    #[test]
    fn tier_names_roundtrip() {
        for t in [Tier::None, Tier::Local, Tier::Global] {
            if t != Tier::None {
                assert_eq!(Tier::from_tier_str(t.name()), t);
            }
        }
        assert_eq!(Tier::from_tier_str("anything"), Tier::None);
    }
}
