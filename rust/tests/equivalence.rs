//! The central correctness invariant of the reproduction: the
//! conventional, intermediate and structure-aware strategies are
//! *observationally equivalent* — same model, same seed, identical spike
//! trains — and results are independent of the number of ranks/threads.
//!
//! This is what licenses the paper's performance comparison: the
//! communication restructuring must not change the dynamics.

use nsim::config::{CommMode, ExecMode, RunConfig, Strategy, UpdatePath};
use nsim::engine::simulate;
use nsim::models;
use nsim::network::ModelSpec;

/// Default-config run (pooled execution): the hot path under test.
fn run(
    spec: &ModelSpec,
    strategy: Strategy,
    m: usize,
    t: usize,
    t_model_ms: f64,
) -> Vec<(u64, u32)> {
    let cfg = RunConfig {
        strategy,
        m_ranks: m,
        threads_per_rank: t,
        t_model_ms,
        seed: 12,
        update_path: UpdatePath::Native,
        record_spikes: true,
        ..RunConfig::default()
    };
    simulate(spec, &cfg).expect("simulation failed").spikes
}

fn run_exec(
    spec: &ModelSpec,
    strategy: Strategy,
    m: usize,
    t: usize,
    t_model_ms: f64,
    exec: ExecMode,
) -> Vec<(u64, u32)> {
    run_comm(spec, strategy, m, t, t_model_ms, exec, CommMode::Blocking)
}

#[allow(clippy::too_many_arguments)]
fn run_comm(
    spec: &ModelSpec,
    strategy: Strategy,
    m: usize,
    t: usize,
    t_model_ms: f64,
    exec: ExecMode,
    comm: CommMode,
) -> Vec<(u64, u32)> {
    run_depth(spec, strategy, m, t, t_model_ms, exec, comm, 1)
}

#[allow(clippy::too_many_arguments)]
fn run_depth(
    spec: &ModelSpec,
    strategy: Strategy,
    m: usize,
    t: usize,
    t_model_ms: f64,
    exec: ExecMode,
    comm: CommMode,
    comm_depth: usize,
) -> Vec<(u64, u32)> {
    let cfg = RunConfig {
        strategy,
        m_ranks: m,
        threads_per_rank: t,
        t_model_ms,
        seed: 12,
        exec,
        comm,
        comm_depth,
        record_spikes: true,
        ..RunConfig::default()
    };
    simulate(spec, &cfg).expect("simulation failed").spikes
}

/// Hierarchical run: structure-aware placement with areas spanning
/// `ranks_per_area`-rank groups (local tier = intra-group alltoall).
#[allow(clippy::too_many_arguments)]
fn run_hier(
    spec: &ModelSpec,
    strategy: Strategy,
    m: usize,
    ranks_per_area: usize,
    t: usize,
    t_model_ms: f64,
    exec: ExecMode,
    comm: CommMode,
    comm_depth: usize,
) -> Vec<(u64, u32)> {
    let cfg = RunConfig {
        strategy,
        m_ranks: m,
        threads_per_rank: t,
        t_model_ms,
        seed: 12,
        exec,
        comm,
        comm_depth,
        ranks_per_area,
        record_spikes: true,
        ..RunConfig::default()
    };
    simulate(spec, &cfg).expect("simulation failed").spikes
}

#[test]
fn ianf_model_identical_across_strategies() {
    let spec = models::mam_benchmark(4, 0.004, 1.0).unwrap(); // 4x520
    let conv = run(&spec, Strategy::Conventional, 4, 2, 50.0);
    let inter = run(&spec, Strategy::Intermediate, 4, 2, 50.0);
    let stru = run(&spec, Strategy::StructureAware, 4, 2, 50.0);
    assert!(!conv.is_empty(), "no spikes emitted");
    assert_eq!(conv, inter, "conventional != intermediate");
    assert_eq!(conv, stru, "conventional != structure-aware");
}

#[test]
fn lif_model_identical_across_strategies() {
    // sanity net has exact binary-fraction weights -> f64 ring-buffer
    // sums are order-independent and spike trains must match exactly
    let spec = models::sanity_net(300, 4).unwrap();
    let conv = run(&spec, Strategy::Conventional, 4, 2, 200.0);
    let inter = run(&spec, Strategy::Intermediate, 4, 2, 200.0);
    let stru = run(&spec, Strategy::StructureAware, 4, 2, 200.0);
    assert!(
        conv.len() > 100,
        "network too quiet for a meaningful test: {} spikes",
        conv.len()
    );
    assert_eq!(conv, inter, "conventional != intermediate");
    assert_eq!(conv, stru, "conventional != structure-aware");
}

#[test]
fn lif_recurrent_dynamics_depend_on_connectivity() {
    // sanity check that the test above isn't vacuous (pure tonic firing):
    // a different connectivity seed must change the spike train
    let spec = models::sanity_net(300, 4).unwrap();
    let a = run(&spec, Strategy::Conventional, 2, 2, 200.0);
    let cfg_b = RunConfig {
        strategy: Strategy::Conventional,
        m_ranks: 2,
        threads_per_rank: 2,
        t_model_ms: 200.0,
        seed: 91856,
        update_path: UpdatePath::Native,
        record_spikes: true,
        ..RunConfig::default()
    };
    let b = simulate(&spec, &cfg_b).unwrap().spikes;
    assert_ne!(a, b, "recurrent input has no effect — test is vacuous");
}

#[test]
fn spike_trains_independent_of_rank_count() {
    let spec = models::sanity_net(240, 8).unwrap();
    let base = run(&spec, Strategy::Conventional, 1, 2, 100.0);
    for m in [2usize, 4, 8] {
        let got = run(&spec, Strategy::Conventional, m, 2, 100.0);
        assert_eq!(base, got, "spike trains differ for M={m}");
    }
    // structure-aware across different rank counts (areas % m == 0)
    let s2 = run(&spec, Strategy::StructureAware, 2, 2, 100.0);
    let s4 = run(&spec, Strategy::StructureAware, 4, 2, 100.0);
    let s8 = run(&spec, Strategy::StructureAware, 8, 2, 100.0);
    assert_eq!(base, s2);
    assert_eq!(base, s4);
    assert_eq!(base, s8);
}

#[test]
fn spike_trains_independent_of_thread_count() {
    let spec = models::sanity_net(240, 4).unwrap();
    let base = run(&spec, Strategy::StructureAware, 4, 1, 100.0);
    for t in [2usize, 3, 8] {
        let got = run(&spec, Strategy::StructureAware, 4, t, 100.0);
        assert_eq!(base, got, "spike trains differ for T={t}");
    }
}

#[test]
fn spike_trains_identical_across_exec_modes() {
    // the tentpole invariant of the parallel execution paths: same seed
    // => identical (step, gid) spike trains across thread counts and
    // across sequential vs barrier-runtime vs legacy channel-pool
    // execution, for both strategies
    let spec = models::sanity_net(240, 4).unwrap();
    for strategy in [Strategy::Conventional, Strategy::StructureAware] {
        let base =
            run_exec(&spec, strategy, 4, 1, 100.0, ExecMode::Sequential);
        assert!(
            base.len() > 100,
            "{}: too quiet for a meaningful test ({} spikes)",
            strategy.name(),
            base.len()
        );
        for t in [1usize, 2, 4] {
            for exec in [
                ExecMode::Sequential,
                ExecMode::Pooled,
                ExecMode::PooledChannels,
            ] {
                let got = run_exec(&spec, strategy, 4, t, 100.0, exec);
                assert_eq!(
                    base,
                    got,
                    "{} diverged at T={t} exec={}",
                    strategy.name(),
                    exec.name()
                );
            }
        }
    }
}

#[test]
fn ianf_model_identical_across_exec_modes() {
    // same invariant on the ignore-and-fire benchmark model
    let spec = models::mam_benchmark(4, 0.004, 1.0).unwrap();
    let base = run_exec(
        &spec,
        Strategy::StructureAware,
        4,
        1,
        50.0,
        ExecMode::Sequential,
    );
    assert!(!base.is_empty());
    for t in [2usize, 4] {
        let got = run_exec(
            &spec,
            Strategy::StructureAware,
            4,
            t,
            50.0,
            ExecMode::Pooled,
        );
        assert_eq!(base, got, "pooled ianf diverged at T={t}");
    }
}

#[test]
fn spike_trains_identical_across_comm_modes() {
    // the tentpole invariant of the split-phase exchange: posting the
    // global alltoall at the epoch boundary and completing it cycles
    // later must not move a single spike, for every strategy and every
    // exec mode, across thread counts
    let spec = models::sanity_net(240, 4).unwrap();
    for strategy in [
        Strategy::Conventional,
        Strategy::Intermediate,
        Strategy::StructureAware,
    ] {
        let base = run_comm(
            &spec,
            strategy,
            4,
            1,
            100.0,
            ExecMode::Sequential,
            CommMode::Blocking,
        );
        assert!(
            base.len() > 100,
            "{}: too quiet for a meaningful test ({} spikes)",
            strategy.name(),
            base.len()
        );
        for exec in [
            ExecMode::Sequential,
            ExecMode::Pooled,
            ExecMode::PooledChannels,
        ] {
            for t in [1usize, 3] {
                let got = run_comm(
                    &spec,
                    strategy,
                    4,
                    t,
                    100.0,
                    exec,
                    CommMode::Overlap,
                );
                assert_eq!(
                    base,
                    got,
                    "{} diverged under overlap at T={t} exec={}",
                    strategy.name(),
                    exec.name()
                );
            }
        }
    }
}

#[test]
fn spike_trains_identical_across_comm_depths() {
    // the tentpole invariant of the depth-D pipeline: keeping several
    // exchange rounds in flight (and draining early deposits source by
    // source during the window) must not move a single spike — across
    // depth x comm mode x exec mode x thread count.  The deep-pipeline
    // net realizes ~5 cycles of delay slack, so conventional runs
    // sustain depth 4.
    let spec = models::deep_pipeline_net(240, 4).unwrap();
    for strategy in [Strategy::Conventional, Strategy::StructureAware] {
        let base = run_depth(
            &spec,
            strategy,
            4,
            1,
            100.0,
            ExecMode::Sequential,
            CommMode::Blocking,
            1,
        );
        assert!(
            base.len() > 100,
            "{}: too quiet for a meaningful test ({} spikes)",
            strategy.name(),
            base.len()
        );
        for depth in [1usize, 2, 4] {
            for exec in [
                ExecMode::Sequential,
                ExecMode::Pooled,
                ExecMode::PooledChannels,
            ] {
                for t in [1usize, 3] {
                    let got = run_depth(
                        &spec,
                        strategy,
                        4,
                        t,
                        100.0,
                        exec,
                        CommMode::Overlap,
                        depth,
                    );
                    assert_eq!(
                        base,
                        got,
                        "{} diverged at depth={depth} T={t} exec={}",
                        strategy.name(),
                        exec.name()
                    );
                }
            }
        }
        // depth is ignored under the blocking collective: same train,
        // and the run is accepted even where overlap would reject it
        let blocking_deep = run_depth(
            &spec,
            strategy,
            4,
            2,
            100.0,
            ExecMode::Pooled,
            CommMode::Blocking,
            64,
        );
        assert_eq!(base, blocking_deep, "{}", strategy.name());
    }
}

#[test]
fn hierarchical_groups_identical_to_flat() {
    // the tentpole invariant of the hierarchical communicator API: an
    // area spanning a multi-rank group — short-range spikes exchanged
    // through a real intra-group alltoall on the area's sub-communicator
    // every cycle — must not move a single spike relative to the flat
    // runs, across exec x comm x depth x threads.  deep_pipeline_net has
    // exact binary-fraction weights and ~4-5 cycles of realized slack,
    // so depth-2 overlap is sustainable on the global tier.
    let spec = models::deep_pipeline_net(240, 4).unwrap();
    let base = run_comm(
        &spec,
        Strategy::Conventional,
        8,
        1,
        100.0,
        ExecMode::Sequential,
        CommMode::Blocking,
    );
    assert!(
        base.len() > 100,
        "too quiet for a meaningful test ({} spikes)",
        base.len()
    );
    // degenerate hierarchy: one rank per area group (must stay
    // bit-identical to the pre-hierarchical engine)
    let flat = run_hier(
        &spec,
        Strategy::StructureAware,
        4,
        1,
        2,
        100.0,
        ExecMode::Pooled,
        CommMode::Blocking,
        1,
    );
    assert_eq!(base, flat, "ranks_per_area=1 diverged from flat");
    // real hierarchy: 4 areas x 2-rank groups on 8 ranks
    for comm in [CommMode::Blocking, CommMode::Overlap] {
        for depth in [1usize, 2] {
            if comm == CommMode::Blocking && depth > 1 {
                continue;
            }
            for exec in [
                ExecMode::Sequential,
                ExecMode::Pooled,
                ExecMode::PooledChannels,
            ] {
                for t in [1usize, 3] {
                    let got = run_hier(
                        &spec,
                        Strategy::StructureAware,
                        8,
                        2,
                        t,
                        100.0,
                        exec,
                        comm,
                        depth,
                    );
                    assert_eq!(
                        base,
                        got,
                        "hierarchical diverged: comm={} depth={depth} \
                         exec={} T={t}",
                        comm.name(),
                        exec.name()
                    );
                }
            }
        }
    }
}

#[test]
fn hierarchical_strategies_and_group_sizes_agree() {
    // sanity net (exact weights): the flat conventional reference vs
    // grouped structure-aware placements at ranks_per_area 2 and 4 —
    // at R=4 each 4-rank group hosts *two* areas, exercising multiple
    // areas per local communicator
    let spec = models::sanity_net(240, 4).unwrap();
    let base = run(&spec, Strategy::Conventional, 8, 2, 100.0);
    assert!(
        base.len() > 100,
        "too quiet for a meaningful test ({} spikes)",
        base.len()
    );
    for rpa in [2usize, 4] {
        for strategy in
            [Strategy::Intermediate, Strategy::StructureAware]
        {
            let got = run_hier(
                &spec,
                strategy,
                8,
                rpa,
                2,
                100.0,
                ExecMode::Pooled,
                CommMode::Blocking,
                1,
            );
            assert_eq!(
                base,
                got,
                "{} diverged at ranks_per_area={rpa}",
                strategy.name()
            );
        }
    }
    // split-phase overlap on the global tier with a grouped local tier
    let got = run_hier(
        &spec,
        Strategy::StructureAware,
        8,
        2,
        2,
        100.0,
        ExecMode::Pooled,
        CommMode::Overlap,
        1,
    );
    assert_eq!(base, got, "overlap diverged under grouping");
}

#[test]
fn hierarchical_tier_stats_attributed() {
    let spec = models::sanity_net(200, 4).unwrap();
    let run_cfg = |rpa: usize, m: usize| {
        let cfg = RunConfig {
            strategy: Strategy::StructureAware,
            m_ranks: m,
            threads_per_rank: 2,
            t_model_ms: 100.0,
            seed: 12,
            ranks_per_area: rpa,
            record_spikes: true,
            ..RunConfig::default()
        };
        simulate(&spec, &cfg).expect("simulation failed")
    };
    // flat: the local tier is the intra-rank swap — no collectives, no
    // wire bytes, one swap per cycle per rank
    let flat = run_cfg(1, 4);
    let lt = &flat.comm_tiers.local;
    assert_eq!(lt.alltoall_calls, 0);
    assert_eq!(lt.local_swaps, flat.s_cycles * 4);
    assert_eq!(lt.bytes_sent, 0);
    assert_eq!(flat.comm_stats, flat.comm_tiers.combined());
    assert_eq!(
        flat.comm_tiers.global.alltoall_calls,
        flat.comm_stats.alltoall_calls
    );

    // hierarchical: a real group alltoall every cycle per rank carrying
    // actual spikes; the global tier still runs once per epoch per rank
    // (plus the preparation exchange)
    let hier = run_cfg(2, 8);
    let lt = &hier.comm_tiers.local;
    assert_eq!(lt.local_swaps, 0);
    assert_eq!(lt.alltoall_calls, hier.s_cycles * 8);
    assert!(lt.bytes_sent > 0, "group exchange moves real spikes");
    let epochs = hier.s_cycles / spec.delay_ratio() as u64;
    assert_eq!(
        hier.comm_tiers.global.alltoall_calls,
        (epochs + 1) * 8
    );
    assert_eq!(hier.comm_stats, hier.comm_tiers.combined());
}

#[test]
fn groups_allow_more_ranks_than_areas() {
    // 4 areas cannot fill 8 ranks one-per-rank (placement rejects the
    // idle ranks), but spanning each area over a 2-rank group can
    let spec = models::sanity_net(120, 4).unwrap();
    let cfg = RunConfig {
        strategy: Strategy::StructureAware,
        m_ranks: 8,
        threads_per_rank: 2,
        t_model_ms: 20.0,
        seed: 12,
        record_spikes: true,
        ..RunConfig::default()
    };
    assert!(
        simulate(&spec, &cfg).is_err(),
        "flat 8-rank run should be short of areas"
    );
    let cfg = RunConfig { ranks_per_area: 2, ..cfg };
    assert!(simulate(&spec, &cfg).is_ok());
}

#[test]
fn excessive_comm_depth_rejected_with_actionable_error() {
    // deep-pipeline net: ~5 cycles of slack sustain at most a handful
    // of rounds in flight; a depth-16 pipeline must be rejected with
    // the sustainable bound in the message
    let spec = models::deep_pipeline_net(150, 2).unwrap();
    let cfg = RunConfig {
        strategy: Strategy::Conventional,
        m_ranks: 2,
        threads_per_rank: 2,
        t_model_ms: 50.0,
        seed: 12,
        comm: CommMode::Overlap,
        comm_depth: 16,
        record_spikes: true,
        ..RunConfig::default()
    };
    let err = match simulate(&spec, &cfg) {
        Err(e) => e,
        Ok(_) => panic!("excessive comm depth was not rejected"),
    };
    let msg = format!("{err:#}");
    assert!(
        msg.contains("exceeds the realized delay slack"),
        "unexpected error: {msg}"
    );
    assert!(msg.contains("--comm-depth"), "unexpected error: {msg}");

    // the sanity net's realized minimum delay is the cutoff itself (one
    // cycle of slack): even depth 2 cannot be sustained conventionally
    let spec = models::sanity_net(200, 2).unwrap();
    let cfg = RunConfig {
        comm_depth: 2,
        t_model_ms: 100.0,
        ..cfg
    };
    assert!(simulate(&spec, &cfg).is_err(), "depth 2 on 1-cycle slack");
    // while depth 1 (the default) runs fine
    let cfg = RunConfig { comm_depth: 1, ..cfg };
    assert!(simulate(&spec, &cfg).is_ok());
}

#[test]
fn depth_pipeline_comm_stats_account_early_drains() {
    // under a deep pipeline the per-cycle poll drains early deposits;
    // the counters must stay consistent with the exchange counts and
    // the effective depth must surface in the result
    let spec = models::deep_pipeline_net(200, 4).unwrap();
    let run_stats = |comm: CommMode, depth: usize| {
        let cfg = RunConfig {
            strategy: Strategy::Conventional,
            m_ranks: 4,
            threads_per_rank: 2,
            t_model_ms: 100.0,
            seed: 12,
            comm,
            comm_depth: depth,
            record_spikes: true,
            ..RunConfig::default()
        };
        simulate(&spec, &cfg).expect("simulation failed")
    };
    let blocking = run_stats(CommMode::Blocking, 1);
    assert_eq!(blocking.effective_comm_depth, 1);
    assert_eq!(blocking.comm_stats.early_drained_sources, 0);

    let deep = run_stats(CommMode::Overlap, 4);
    assert_eq!(deep.effective_comm_depth, 4);
    let cs = &deep.comm_stats;
    // traffic identical to blocking, only its phasing differs
    assert_eq!(cs.alltoall_calls, blocking.comm_stats.alltoall_calls);
    assert_eq!(cs.bytes_sent, blocking.comm_stats.bytes_sent);
    assert!(cs.overlapped_exchanges > 0);
    // every early-drained source belongs to exactly one completed
    // exchange, and each exchange has at most m sources to drain
    assert!(
        cs.early_drained_sources <= cs.overlapped_exchanges * 4,
        "{cs:?}"
    );
    // with ~4 in-flight cycles per exchange the fast path should catch
    // a decent share of deposits before the deadline rendezvous
    assert!(cs.early_drained_sources > 0, "{cs:?}");
    // duration ledger: nothing negative, post/wait/hidden all tracked
    assert!(cs.post_secs >= 0.0);
    assert!(cs.complete_wait_secs >= 0.0);
    assert!(cs.hidden_secs >= 0.0);
}

#[test]
fn overlap_comm_stats_track_split_phase_traffic() {
    // under overlap every epoch-boundary exchange is split-phase: the
    // overlapped counter equals the alltoall count and the byte/call
    // totals match the blocking run exactly
    let spec = models::sanity_net(200, 4).unwrap();
    let run_stats = |comm: CommMode| {
        let cfg = RunConfig {
            strategy: Strategy::StructureAware,
            m_ranks: 4,
            threads_per_rank: 2,
            t_model_ms: 100.0,
            seed: 12,
            comm,
            record_spikes: true,
            ..RunConfig::default()
        };
        simulate(&spec, &cfg).expect("simulation failed").comm_stats
    };
    let blocking = run_stats(CommMode::Blocking);
    let overlap = run_stats(CommMode::Overlap);
    assert_eq!(blocking.overlapped_exchanges, 0);
    assert_eq!(blocking.hidden_secs, 0.0);
    assert!(overlap.alltoall_calls > 0);
    // the engine's collective traffic is identical, only its phasing
    // differs (the preparation exchange stays blocking in both modes)
    assert_eq!(overlap.alltoall_calls, blocking.alltoall_calls);
    assert_eq!(overlap.bytes_sent, blocking.bytes_sent);
    assert_eq!(overlap.local_swaps, blocking.local_swaps);
    // every run-loop exchange was split-phase: one blocking collective
    // per rank remains from the target-table preparation
    assert_eq!(
        overlap.overlapped_exchanges + 4,
        overlap.alltoall_calls,
        "expected all run-loop exchanges overlapped"
    );
    assert!(overlap.hidden_secs >= 0.0);
}

#[test]
fn partial_tail_epoch_rejected_for_structure_aware() {
    // 10.5 ms at h=0.1 and D=10 leaves a 5-cycle partial epoch whose
    // long-range spikes would silently never be exchanged
    let spec = models::sanity_net(120, 2).unwrap();
    let cfg = RunConfig {
        strategy: Strategy::StructureAware,
        m_ranks: 2,
        threads_per_rank: 2,
        t_model_ms: 10.5,
        seed: 12,
        record_spikes: true,
        ..RunConfig::default()
    };
    let err = match simulate(&spec, &cfg) {
        Err(e) => e,
        Ok(_) => panic!("partial tail epoch was not rejected"),
    };
    assert!(
        format!("{err:#}").contains("partial epoch"),
        "unexpected error: {err:#}"
    );
    // conventional communicates every cycle: same t_model is fine
    let cfg = RunConfig {
        strategy: Strategy::Conventional,
        ..cfg
    };
    assert!(simulate(&spec, &cfg).is_ok());
}

#[test]
fn tiny_comm_quota_equivalent_to_default() {
    // a starting quota of 1 forces the two-round resize protocol to fire
    // under real engine traffic — in both its blocking (barrier-agreed)
    // and split-phase (rendezvous-settled) forms; dynamics must not
    // change either way
    let spec = models::sanity_net(200, 2).unwrap();
    let run_quota = |quota: usize, comm: CommMode| {
        let cfg = RunConfig {
            strategy: Strategy::Conventional,
            m_ranks: 2,
            threads_per_rank: 2,
            t_model_ms: 100.0,
            seed: 12,
            comm,
            comm_quota: quota,
            record_spikes: true,
            ..RunConfig::default()
        };
        simulate(&spec, &cfg).expect("simulation failed").spikes
    };
    let tiny = run_quota(1, CommMode::Blocking);
    let default = run_quota(4096, CommMode::Blocking);
    assert!(!tiny.is_empty());
    assert_eq!(tiny, default, "quota resize protocol changed dynamics");
    let tiny_overlap = run_quota(1, CommMode::Overlap);
    assert_eq!(
        tiny, tiny_overlap,
        "split-phase quota resize changed dynamics"
    );
}

#[test]
fn delay_ratio_sweep_preserves_dynamics() {
    // increasing the inter-area cutoff changes delays (hence dynamics),
    // but for a fixed cutoff the strategies must agree for every D
    for d_min_inter in [0.5, 1.0, 2.0] {
        let spec = models::mam_benchmark(4, 0.002, d_min_inter).unwrap();
        let conv = run(&spec, Strategy::Conventional, 4, 2, 30.0);
        let stru = run(&spec, Strategy::StructureAware, 4, 2, 30.0);
        assert_eq!(conv, stru, "mismatch at d_min_inter={d_min_inter}");
    }
}

#[test]
fn more_areas_than_ranks_supported() {
    // 8 areas on 4 ranks: two areas per rank; intra-area spikes of both
    // areas stay rank-local
    let spec = models::mam_benchmark(8, 0.002, 1.0).unwrap();
    let conv = run(&spec, Strategy::Conventional, 4, 2, 30.0);
    let stru = run(&spec, Strategy::StructureAware, 4, 2, 30.0);
    assert_eq!(conv, stru);
}

#[test]
fn single_rank_structure_aware_works() {
    let spec = models::mam_benchmark(2, 0.002, 1.0).unwrap();
    let conv = run(&spec, Strategy::Conventional, 1, 2, 30.0);
    let stru = run(&spec, Strategy::StructureAware, 1, 2, 30.0);
    assert_eq!(conv, stru);
}

#[test]
fn randomized_configurations_property() {
    // random (areas, size, ranks, threads, D) configurations: strategies
    // must agree pairwise on every draw
    use nsim::util::rng::Pcg64;
    let mut rng = Pcg64::seed_from_u64(0xE0);
    for case in 0..5 {
        let n_areas = 2 + rng.below(4) as usize; // 2..5
        let m = 1 + rng.below(n_areas as u64) as usize;
        let t = 1 + rng.below(3) as usize;
        let n = 120 + rng.below(200) as u32;
        let d_min_inter = [0.5, 1.0, 2.0][rng.below(3) as usize];
        let spec =
            models::mam_benchmark(n_areas, n as f64 / 130_000.0, d_min_inter)
                .unwrap();
        let conv = run(&spec, Strategy::Conventional, m, t, 20.0);
        let stru = run(&spec, Strategy::StructureAware, m, t, 20.0);
        assert_eq!(
            conv, stru,
            "case {case}: areas={n_areas} m={m} t={t} n={n} \
             d_inter={d_min_inter}"
        );
    }
}

#[test]
fn observability_does_not_perturb_dynamics() {
    // span tracing, interval histograms and straggler blame are
    // timing-only observers: turning all of them on (plus the raw
    // per-cycle vectors) must not move a single spike — across
    // exec x comm x depth x hierarchy
    let spec = models::deep_pipeline_net(240, 4).unwrap();
    let run_obs = |m: usize,
                   rpa: usize,
                   t: usize,
                   exec: ExecMode,
                   comm: CommMode,
                   depth: usize,
                   obs: bool| {
        let cfg = RunConfig {
            strategy: Strategy::StructureAware,
            m_ranks: m,
            threads_per_rank: t,
            t_model_ms: 100.0,
            seed: 12,
            exec,
            comm,
            comm_depth: depth,
            ranks_per_area: rpa,
            record_spikes: true,
            trace: obs,
            record_cycle_times: obs,
            ..RunConfig::default()
        };
        simulate(&spec, &cfg).expect("simulation failed")
    };
    for (m, rpa, exec, comm, depth, t) in [
        (4usize, 1usize, ExecMode::Sequential, CommMode::Blocking, 1usize, 1usize),
        (4, 1, ExecMode::Pooled, CommMode::Overlap, 2, 3),
        (4, 1, ExecMode::PooledChannels, CommMode::Blocking, 1, 2),
        (8, 2, ExecMode::Pooled, CommMode::Overlap, 2, 2),
        (8, 2, ExecMode::Sequential, CommMode::Blocking, 1, 1),
    ] {
        let off = run_obs(m, rpa, t, exec, comm, depth, false);
        let on = run_obs(m, rpa, t, exec, comm, depth, true);
        assert!(
            off.spikes.len() > 100,
            "too quiet for a meaningful test ({} spikes)",
            off.spikes.len()
        );
        assert_eq!(
            off.spikes,
            on.spikes,
            "observability changed dynamics: m={m} rpa={rpa} exec={} \
             comm={} depth={depth} T={t}",
            exec.name(),
            comm.name()
        );
        // the traced run actually observed something; the untraced run
        // recorded no spans at all
        assert!(off.spans.is_empty());
        assert!(!on.spans.is_empty());
        // the streaming interval stats are always on and span the run
        assert_eq!(off.intervals.len(), m);
        assert_eq!(off.intervals[0].local.n, off.s_cycles);
    }
}

#[test]
fn ianf_rate_matches_target() {
    let spec = models::mam_benchmark(2, 0.01, 1.0).unwrap();
    let spikes = run(&spec, Strategy::Conventional, 2, 2, 1000.0);
    let n = spec.total_neurons() as f64;
    let rate = spikes.len() as f64 / n; // 1 s of model time
    assert!(
        (rate - 2.5).abs() < 0.1,
        "ignore-and-fire rate {rate} != 2.5 Hz"
    );
}
