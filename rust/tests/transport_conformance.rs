//! Transport conformance: the behavioral contract every
//! [`SplitTransport`] backend must honor, written once and instantiated
//! for both backends — the in-process shared-memory `World` and the
//! Unix-domain-socket mesh (exercised here as one mesh of in-process
//! threads; the wire path, framing and demultiplexer are exactly the
//! ones the multi-process launcher uses).
//!
//! Covered invariants: per-pair payload routing and order across
//! repeated rounds (barrier/sequence framing), quota growth mid-flight,
//! `allreduce_min_u64` round isolation, split sub-world isolation and
//! `(key, rank)` sub-rank ordering, the depth-D split-phase ring with
//! early per-source drains and slot recycling, and watchdog timeouts
//! that name the missing rank.

use std::time::{Duration, Instant};

use nsim::comm::{
    CommError, Communicator, Pending, SpikeMsg, SplitTransport,
    Transport, World, WorldBuilder,
};

/// Per-rank transport factory.  The shared-memory fabric hands out
/// communicators of one pre-built `World`; the socket fabric performs a
/// real rendezvous per rank over a private socket directory.
trait Fabric: Sync {
    type T: SplitTransport + Send;
    fn connect(&self, rank: usize) -> Self::T;
}

struct ShmemFabric {
    world: World,
}

fn shmem(m: usize, quota: usize, depth: usize, ms: u64) -> ShmemFabric {
    ShmemFabric {
        world: WorldBuilder::new(m)
            .quota(quota)
            .depth(depth)
            .timeout(Some(Duration::from_millis(ms)))
            .build(),
    }
}

impl Fabric for ShmemFabric {
    type T = Communicator;
    fn connect(&self, rank: usize) -> Communicator {
        self.world.communicator(rank)
    }
}

#[cfg(unix)]
struct SocketFabric {
    m: usize,
    quota: usize,
    depth: usize,
    timeout: Duration,
    dir: std::path::PathBuf,
}

#[cfg(unix)]
fn socket(
    m: usize,
    quota: usize,
    depth: usize,
    ms: u64,
    tag: &str,
) -> SocketFabric {
    let dir = std::env::temp_dir()
        .join(format!("nsim-conf-{}-{tag}", std::process::id()));
    SocketFabric {
        m,
        quota,
        depth,
        timeout: Duration::from_millis(ms),
        dir,
    }
}

#[cfg(unix)]
impl Fabric for SocketFabric {
    type T = nsim::comm::socket::SocketComm;
    fn connect(&self, rank: usize) -> Self::T {
        nsim::comm::socket::SocketWorldBuilder::new(
            self.m, rank, &self.dir,
        )
        .quota(self.quota)
        .depth(self.depth)
        .timeout(Some(self.timeout))
        .connect()
        .expect("socket rendezvous failed")
    }
}

#[cfg(unix)]
impl Drop for SocketFabric {
    fn drop(&mut self) {
        let _ = std::fs::remove_dir_all(&self.dir);
    }
}

/// Run `body(rank, transport)` on one thread per rank.  A panicking
/// rank propagates out of the scope and fails the test; the watchdog
/// deadline armed on every fabric keeps the surviving ranks from
/// hanging on the dead one.
fn run_ranks<F: Fabric>(
    fab: &F,
    m: usize,
    body: impl Fn(usize, F::T) + Sync,
) {
    std::thread::scope(|s| {
        for r in 0..m {
            let body = &body;
            s.spawn(move || body(r, fab.connect(r)));
        }
    });
}

fn msg(source: u32, cycle: u32) -> SpikeMsg {
    SpikeMsg { source, cycle }
}

// ---------------------------------------------------------------- //
// generic contract checks                                          //
// ---------------------------------------------------------------- //

/// Every (src, dst) pair carries a distinct payload across repeated
/// rounds: nothing leaks across pairs or rounds, per-pair order is
/// preserved, and unequal per-pair counts are routed exactly.
fn check_alltoall_routing<F: Fabric>(fab: &F, m: usize) {
    run_ranks(fab, m, |r, comm| {
        assert_eq!(comm.rank(), r);
        assert_eq!(comm.m_ranks(), m);
        for round in 0..3u32 {
            let mut send: Vec<Vec<SpikeMsg>> = (0..m)
                .map(|d| {
                    (0..(r + d + 1) as u32)
                        .map(|i| msg((100 * r + 10 * d) as u32 + i, round))
                        .collect()
                })
                .collect();
            let mut recv = Vec::new();
            comm.alltoall_into(&mut send, &mut recv).expect("alltoall");
            assert_eq!(recv.len(), m);
            for (src, got) in recv.iter().enumerate() {
                let want: Vec<SpikeMsg> = (0..(src + r + 1) as u32)
                    .map(|i| msg((100 * src + 10 * r) as u32 + i, round))
                    .collect();
                assert_eq!(
                    got, &want,
                    "rank {r} from {src} in round {round}"
                );
            }
        }
    });
}

/// Starting from a quota of 1, bursts far beyond it must still arrive
/// complete and in order (the resize protocol settles mid-flight), and
/// the settled quota covers the observed maximum.
fn check_quota_resize<F: Fabric>(fab: &F, m: usize) {
    run_ranks(fab, m, |r, comm| {
        assert_eq!(comm.quota(), 1);
        for &burst in &[64usize, 3, 128] {
            let mut send: Vec<Vec<SpikeMsg>> = (0..m)
                .map(|d| {
                    (0..burst)
                        .map(|i| msg((4096 * r + 512 * d + i) as u32, 9))
                        .collect()
                })
                .collect();
            let mut recv = Vec::new();
            comm.alltoall_into(&mut send, &mut recv).expect("alltoall");
            for (src, got) in recv.iter().enumerate() {
                assert_eq!(got.len(), burst, "rank {r} from {src}");
                for (i, s) in got.iter().enumerate() {
                    assert_eq!(
                        s.source,
                        (4096 * src + 512 * r + i) as u32
                    );
                }
            }
        }
        assert!(comm.quota() >= 128, "quota never settled");
    });
}

/// `allreduce_min_u64` rounds never mix: ten back-to-back reductions
/// with distinct per-round values each return their own global minimum.
fn check_allreduce_rounds<F: Fabric>(fab: &F, m: usize) {
    run_ranks(fab, m, |r, comm| {
        for round in 0..10u64 {
            let mine = round * 100 + (r as u64 * 7 + round) % 50;
            let got = comm.allreduce_min_u64(mine).expect("allreduce");
            let want = (0..m as u64)
                .map(|q| round * 100 + (q * 7 + round) % 50)
                .min()
                .unwrap();
            assert_eq!(got, want, "rank {r} in round {round}");
        }
    });
}

/// `split(color, key)` groups by color, orders sub-ranks by `(key,
/// parent rank)`, and fully isolates the sub-worlds' traffic.
fn check_split_isolation<F: Fabric>(fab: &F) {
    let m = 4;
    run_ranks(fab, m, |r, comm| {
        let color = (r % 2) as u64;
        // inverted keys: the higher parent rank of each color pair
        // must become sub-rank 0
        let key = (m - r) as u64;
        let sub = comm.split(color, key).expect("split");
        assert_eq!(sub.m_ranks(), 2);
        let my_sub = if r < 2 { 1 } else { 0 };
        assert_eq!(sub.rank(), my_sub, "parent rank {r}");
        let peer = (r + 2) % m; // same color, other member
        let mut send: Vec<Vec<SpikeMsg>> = (0..2)
            .map(|d| vec![msg((10 * r + d) as u32, 7)])
            .collect();
        let mut recv = Vec::new();
        sub.alltoall_into(&mut send, &mut recv).expect("sub alltoall");
        assert_eq!(recv.len(), 2);
        // from the peer: the message it addressed to my sub-rank;
        // from myself: my own self-addressed message
        assert_eq!(recv[1 - my_sub], vec![msg(
            (10 * peer + my_sub) as u32,
            7,
        )]);
        assert_eq!(recv[my_sub], vec![msg((10 * r + my_sub) as u32, 7)]);
        // the sub-world's reduction only sees its own color
        let got = sub.allreduce_min_u64(100 + r as u64).expect("reduce");
        assert_eq!(got, 100 + r.min(peer) as u64);
    });
}

/// Depth-2 split-phase pipeline: two exchanges in flight, epochs never
/// mix, and six more epochs recycle every one of the `2·depth` ring
/// slots with correct payloads.
fn check_depth_ring<F: Fabric>(fab: &F, m: usize) {
    run_ranks(fab, m, |r, comm| {
        let payload = |e: u32, src: usize, dst: usize| {
            vec![msg((1000 * e as usize + 10 * src + dst) as u32, e)]
        };
        let sends = |e: u32| -> Vec<Vec<SpikeMsg>> {
            (0..m).map(|d| payload(e, r, d)).collect()
        };
        let check = |e: u32, recv: &[Vec<SpikeMsg>]| {
            assert_eq!(recv.len(), m);
            for (src, got) in recv.iter().enumerate() {
                assert_eq!(
                    got,
                    &payload(e, src, r),
                    "rank {r} from {src} in epoch {e}"
                );
            }
        };
        let mut pending = std::collections::VecDeque::new();
        for e in 0..8u32 {
            let mut s = sends(e);
            pending.push_back((e, comm.alltoall_start(&mut s).unwrap()));
            assert!(s.iter().all(Vec::is_empty), "send bufs not drained");
            if pending.len() == 2 {
                let (done, p) = pending.pop_front().unwrap();
                let mut recv = Vec::new();
                p.complete(&mut recv).expect("complete");
                check(done, &recv);
            }
        }
        while let Some((done, p)) = pending.pop_front() {
            let mut recv = Vec::new();
            p.complete(&mut recv).expect("complete");
            check(done, &recv);
        }
    });
}

/// `try_complete_source` drains one source early without blocking; the
/// final `complete` skips it and still delivers everyone else.
fn check_early_drain<F: Fabric>(fab: &F, m: usize) {
    run_ranks(fab, m, |r, comm| {
        let mut send: Vec<Vec<SpikeMsg>> = (0..m)
            .map(|d| vec![msg((10 * r + d) as u32, 3)])
            .collect();
        let mut p = comm.alltoall_start(&mut send).expect("start");
        let src = (r + 1) % m;
        let mut early = Vec::new();
        let deadline = Instant::now() + Duration::from_secs(10);
        while !p.try_complete_source(src, &mut early).expect("try") {
            assert!(
                Instant::now() < deadline,
                "rank {r}: source {src} never arrived"
            );
            std::thread::yield_now();
        }
        assert_eq!(early, vec![msg((10 * src + r) as u32, 3)]);
        // a second call reports the drain without touching `out`
        let mut untouched = vec![msg(u32::MAX, 0)];
        assert!(p.try_complete_source(src, &mut untouched).unwrap());
        assert_eq!(untouched, vec![msg(u32::MAX, 0)]);
        let mut recv = Vec::new();
        p.complete(&mut recv).expect("complete");
        for (s, got) in recv.iter().enumerate() {
            if s == src {
                continue; // early-drained: complete() skipped it
            }
            assert_eq!(
                got,
                &vec![msg((10 * s + r) as u32, 3)],
                "rank {r} from {s}"
            );
        }
    });
}

/// A rank that never shows up trips the watchdog on its peer, and the
/// typed timeout names exactly the missing rank.
fn check_timeout_names_missing<F: Fabric>(fab: &F) {
    run_ranks(fab, 2, |r, comm| {
        if r == 1 {
            // never participates — outlive the peer's watchdog so the
            // deadline (not our teardown) is what fires first
            std::thread::sleep(Duration::from_millis(600));
            drop(comm);
            return;
        }
        let mut send: Vec<Vec<SpikeMsg>> =
            (0..2).map(|_| Vec::new()).collect();
        let mut recv = Vec::new();
        match comm.alltoall_into(&mut send, &mut recv) {
            Err(CommError::Timeout { missing, present, rank, .. }) => {
                assert_eq!(rank, 0);
                assert_eq!(missing, vec![1]);
                assert!(!present.contains(&1));
            }
            Err(e) => panic!("expected a timeout, got: {e}"),
            Ok(_) => panic!("the exchange cannot have completed"),
        }
    });
}

// ---------------------------------------------------------------- //
// instantiations                                                   //
// ---------------------------------------------------------------- //

#[test]
fn shmem_alltoall_routing() {
    check_alltoall_routing(&shmem(4, 64, 1, 10_000), 4);
}

#[cfg(unix)]
#[test]
fn socket_alltoall_routing() {
    check_alltoall_routing(&socket(4, 64, 1, 10_000, "routing"), 4);
}

#[test]
fn shmem_quota_resize() {
    check_quota_resize(&shmem(3, 1, 1, 10_000), 3);
}

#[cfg(unix)]
#[test]
fn socket_quota_resize() {
    check_quota_resize(&socket(3, 1, 1, 10_000, "quota"), 3);
}

#[test]
fn shmem_allreduce_rounds() {
    check_allreduce_rounds(&shmem(4, 16, 1, 10_000), 4);
}

#[cfg(unix)]
#[test]
fn socket_allreduce_rounds() {
    check_allreduce_rounds(&socket(4, 16, 1, 10_000, "reduce"), 4);
}

#[test]
fn shmem_split_isolation() {
    check_split_isolation(&shmem(4, 16, 1, 10_000));
}

#[cfg(unix)]
#[test]
fn socket_split_isolation() {
    check_split_isolation(&socket(4, 16, 1, 10_000, "split"));
}

#[test]
fn shmem_depth_ring() {
    check_depth_ring(&shmem(3, 16, 2, 10_000), 3);
}

#[cfg(unix)]
#[test]
fn socket_depth_ring() {
    check_depth_ring(&socket(3, 16, 2, 10_000, "ring"), 3);
}

#[test]
fn shmem_early_drain() {
    check_early_drain(&shmem(3, 16, 1, 10_000), 3);
}

#[cfg(unix)]
#[test]
fn socket_early_drain() {
    check_early_drain(&socket(3, 16, 1, 10_000, "drain"), 3);
}

#[test]
fn shmem_timeout_names_missing_rank() {
    check_timeout_names_missing(&shmem(2, 16, 1, 150));
}

#[cfg(unix)]
#[test]
fn socket_timeout_names_missing_rank() {
    check_timeout_names_missing(&socket(2, 16, 1, 150, "timeout"));
}
