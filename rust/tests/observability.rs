//! The observability subsystem end to end: trace well-formedness
//! (spans nest, attribution is coherent, the exported document is valid
//! Chrome trace-event JSON), straggler attribution under injected
//! faults (the blamed rank is the inflated one), and the stability of
//! the `--stats-json` schema.
//!
//! The *non-perturbation* invariant — spike trains bit-identical with
//! observability on vs off — lives in `tests/equivalence.rs` next to
//! the other equivalence properties.

use nsim::config::{
    CommMode, ExecMode, RunConfig, StragglerFault, Strategy,
};
use nsim::engine::{simulate, SimResult};
use nsim::models;
use nsim::obs::{SpanEvent, Tier};
use nsim::util::json::{self, Json};

fn traced_run(
    strategy: Strategy,
    m: usize,
    rpa: usize,
    t: usize,
    comm: CommMode,
) -> SimResult {
    let spec = models::sanity_net(240, 4).unwrap();
    let cfg = RunConfig {
        strategy,
        m_ranks: m,
        threads_per_rank: t,
        t_model_ms: 50.0,
        seed: 12,
        comm,
        ranks_per_area: rpa,
        record_spikes: true,
        trace: true,
        ..RunConfig::default()
    };
    simulate(&spec, &cfg).expect("simulation failed")
}

/// Every span name the engine and comm layers may emit.
const KNOWN_SPANS: &[&str] = &[
    "deliver",
    "update",
    "collocate",
    "straggle",
    "checkpoint",
    "split",
    "alltoall",
    "alltoall (sync barrier)",
    "alltoall (overflow vote)",
    "alltoall (resize round)",
    "alltoall (deposit)",
    "alltoall (drain)",
    "allreduce_min",
    "post",
    "drain",
    "complete",
    "abandon",
];

/// Stack-nesting check for one rank's timeline: spans (already in
/// drain order — by start, longest first) must be properly nested or
/// disjoint, never partially overlapping.
fn assert_nested(rank: &[&SpanEvent]) {
    let mut stack: Vec<(f64, &str)> = Vec::new();
    for s in rank {
        let end = s.ts_us + s.dur_us;
        while let Some(&(top_end, _)) = stack.last() {
            if top_end <= s.ts_us {
                stack.pop();
            } else {
                break;
            }
        }
        if let Some(&(top_end, top_name)) = stack.last() {
            assert!(
                end <= top_end,
                "span {:?} [{}, {end}] partially overlaps enclosing \
                 {top_name:?} ending at {top_end}",
                s.name,
                s.ts_us
            );
        }
        stack.push((end, s.name));
    }
}

#[test]
fn trace_spans_are_well_formed() {
    for (strategy, m, rpa, comm) in [
        (Strategy::Conventional, 4, 1, CommMode::Blocking),
        (Strategy::StructureAware, 4, 1, CommMode::Overlap),
        (Strategy::StructureAware, 8, 2, CommMode::Blocking),
    ] {
        let res = traced_run(strategy, m, rpa, 2, comm);
        assert!(!res.spans.is_empty(), "trace recorded nothing");
        for s in &res.spans {
            assert!((s.pid as usize) < m, "pid {} out of range", s.pid);
            assert_eq!(s.tid, 0);
            assert!(s.ts_us >= 0.0 && s.dur_us >= 0.0, "{s:?}");
            assert!(
                KNOWN_SPANS.contains(&s.name),
                "unknown span name {:?}",
                s.name
            );
            if s.ctx.src >= 0 {
                assert!((s.ctx.src as usize) < m, "{s:?}");
                assert_ne!(s.ctx.src as u32, s.pid, "self-blame: {s:?}");
            }
        }
        // drain order: grouped by rank, sorted by start (ties: longest
        // first, so parents precede children)
        for w in res.spans.windows(2) {
            let (a, b) = (&w[0], &w[1]);
            assert!(
                a.pid < b.pid
                    || (a.pid == b.pid
                        && (a.ts_us < b.ts_us
                            || (a.ts_us == b.ts_us
                                && a.dur_us >= b.dur_us))),
                "drain order violated: {a:?} then {b:?}"
            );
        }
        for r in 0..m {
            let rank: Vec<&SpanEvent> = res
                .spans
                .iter()
                .filter(|s| s.pid as usize == r)
                .collect();
            assert_nested(&rank);
            // the engine phases are all there, attributed to cycles
            for phase in ["deliver", "update", "collocate"] {
                let n = rank.iter().filter(|s| s.name == phase).count();
                assert_eq!(
                    n as u64, res.s_cycles,
                    "rank {r}: {phase} spans != cycles"
                );
            }
            assert!(
                rank.iter()
                    .filter(|s| s.name == phase_of(comm))
                    .all(|s| s.ctx.tier != Tier::None),
                "rank {r}: comm span missing tier attribution"
            );
        }
        // hierarchical runs exercise the local tier every cycle
        if rpa > 1 {
            assert!(
                res.spans.iter().any(|s| s.name == "alltoall"
                    && s.ctx.tier == Tier::Local),
                "no local-tier alltoall spans in hierarchical run"
            );
        }
    }
}

/// The comm span characteristic of the mode: the framed collective
/// under blocking, the split-phase completion under overlap.
fn phase_of(comm: CommMode) -> &'static str {
    match comm {
        CommMode::Blocking => "alltoall",
        CommMode::Overlap => "complete",
    }
}

#[test]
fn exported_trace_is_valid_chrome_json() {
    let res = traced_run(Strategy::StructureAware, 4, 1, 2, CommMode::Blocking);
    let path = std::env::temp_dir().join(format!(
        "nsim-obs-{}-trace.json",
        std::process::id()
    ));
    nsim::obs::trace::write_chrome_trace(&path, &res.spans, res.m_ranks)
        .expect("trace write failed");
    let text = std::fs::read_to_string(&path).unwrap();
    std::fs::remove_file(&path).ok();
    let doc = json::parse(&text).expect("trace is not valid JSON");
    let events = doc
        .get("traceEvents")
        .and_then(|v| v.as_arr())
        .expect("no traceEvents array");
    // metadata names every rank's process, then one X event per span
    let meta = events
        .iter()
        .filter(|e| e.get("ph").and_then(|p| p.as_str()) == Some("M"))
        .count();
    assert_eq!(meta, res.m_ranks);
    let xs: Vec<_> = events
        .iter()
        .filter(|e| e.get("ph").and_then(|p| p.as_str()) == Some("X"))
        .collect();
    assert_eq!(xs.len(), res.spans.len());
    for e in &xs {
        assert!(e.get("name").and_then(|v| v.as_str()).is_some());
        assert!(e.get("ts").and_then(Json::as_f64).unwrap() >= 0.0);
        assert!(e.get("dur").and_then(Json::as_f64).unwrap() >= 0.0);
        assert!(e.get("pid").and_then(Json::as_usize).unwrap() < 4);
    }
}

#[test]
fn straggler_attribution_blames_the_injected_rank() {
    // inflate rank 2's update phase hard; every other rank's blame
    // ledger must name rank 2 as its dominant last arriver, and the
    // inflation must show in rank 2's interval distribution
    let spec = models::sanity_net(240, 4).unwrap();
    let mut cfg = RunConfig {
        strategy: Strategy::Conventional,
        m_ranks: 4,
        threads_per_rank: 1,
        t_model_ms: 50.0,
        seed: 12,
        exec: ExecMode::Sequential,
        record_spikes: true,
        ..RunConfig::default()
    };
    cfg.faults.stragglers.push(StragglerFault {
        rank: 2,
        factor: 50.0,
        from_epoch: 0,
        to_epoch: u64::MAX,
    });
    let res = simulate(&spec, &cfg).expect("simulation failed");

    let all = res.blame.merged_all();
    let (top_rank, waits, late) =
        all.top().expect("no blame recorded at all");
    assert_eq!(top_rank, 2, "blamed {top_rank}, injected 2");
    assert!(waits > 0 && late > 0.0, "empty top entry: {waits} {late}");
    // per-rank ledgers: every other rank's own top culprit is rank 2,
    // and nobody ever blames themselves
    for r in 0..4usize {
        let b = &res.blame.global[r];
        assert_eq!(b.waits.get(r).copied().unwrap_or(0), 0, "self-blame");
        if r != 2 {
            let (culprit, w, _) =
                b.top().unwrap_or_else(|| panic!("rank {r}: empty ledger"));
            assert_eq!(culprit, 2, "rank {r} blames {culprit}");
            assert!(w > 0);
        }
    }
    // the straggler's compute intervals are visibly inflated
    let mean = |r: usize| res.intervals[r].local.mean;
    assert!(
        mean(2) > 2.0 * mean(0),
        "straggler interval mean {} not inflated vs peer {}",
        mean(2),
        mean(0)
    );
}

#[test]
fn stats_json_schema_is_stable() {
    // the machine-readable contract of --stats-json: schema tag and the
    // section layout downstream tooling (tools/trace_summary.py) keys on
    let res = traced_run(Strategy::StructureAware, 4, 1, 2, CommMode::Blocking);
    let cfg = RunConfig {
        strategy: Strategy::StructureAware,
        m_ranks: 4,
        trace: true,
        ..RunConfig::default()
    };
    let doc = nsim::obs::report::run_report("sanity-240", &cfg, &res);
    assert_eq!(
        doc.get("schema").and_then(|v| v.as_str()),
        Some("nsim-stats-v1")
    );
    for section in [
        "config",
        "result",
        "phase_times",
        "comm",
        "intervals",
        "stragglers",
        "sync_model",
    ] {
        assert!(doc.get(section).is_some(), "missing section {section}");
    }
    let config = doc.get("config").unwrap();
    assert_eq!(
        config.get("model").and_then(|v| v.as_str()),
        Some("sanity-240")
    );
    assert_eq!(config.get("m_ranks").and_then(|v| v.as_usize()), Some(4));
    // one interval summary per rank, each with the histogram keys
    let ints = doc.get("intervals").and_then(|v| v.as_arr()).unwrap();
    assert_eq!(ints.len(), 4);
    for t in ints {
        let local = t.get("local").expect("no local tier");
        for key in
            ["n", "mean_secs", "std_dev_secs", "cv", "p50_secs", "p99_secs"]
        {
            assert!(local.get(key).is_some(), "missing interval key {key}");
        }
        assert!(local.get("n").and_then(|v| v.as_u64()).unwrap() > 0);
    }
    // the sync model fitted from the measured intervals, with predicted
    // and measured T_sync for both tiers
    let sm = doc.get("sync_model").unwrap();
    assert!(sm.get("fitted").unwrap().get("mu_secs").is_some());
    for tier in ["global", "local"] {
        let t = sm.get("tiers").unwrap().get(tier).unwrap();
        assert!(t.get("predicted_secs").is_some());
        assert!(t.get("measured_secs").is_some());
    }
    // straggler section mirrors the in-memory ledgers
    let st = doc.get("stragglers").unwrap();
    assert_eq!(st.get("global").and_then(|v| v.as_arr()).unwrap().len(), 4);
}
