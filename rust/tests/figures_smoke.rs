//! Every figure harness must run end-to-end and report numbers with the
//! paper's qualitative shape (who wins, what grows, where it saturates).
//! Short model times keep this fast; the full protocol runs via
//! `cargo bench --bench figures`.

use nsim::figures::{run_figure, FigOptions, ALL_FIGURES};
use nsim::util::json::Json;

fn opts() -> FigOptions {
    FigOptions { t_model_ms: 200.0, seed: 654 }
}

fn get(j: &Json, key: &str) -> f64 {
    j.get(key)
        .and_then(Json::as_f64)
        .unwrap_or_else(|| panic!("missing key {key}"))
}

#[test]
fn all_figures_run_and_emit() {
    let dir = tempdir();
    for name in ALL_FIGURES {
        let fig = run_figure(name, &opts())
            .unwrap_or_else(|e| panic!("{name} failed: {e:#}"));
        assert!(!fig.table.is_empty(), "{name}: empty table");
        fig.emit(&dir).unwrap();
        assert!(
            std::path::Path::new(&format!("{dir}/{name}.json")).exists(),
            "{name}: no JSON written"
        );
    }
    std::fs::remove_dir_all(&dir).ok();
}

fn tempdir() -> String {
    let dir = std::env::temp_dir().join(format!(
        "nsim-figtest-{}",
        std::process::id()
    ));
    dir.to_string_lossy().into_owned()
}

#[test]
fn fig4_alltoall_reduction_near_paper() {
    let fig = run_figure("fig4", &opts()).unwrap();
    let red = get(&fig.json, "data_reduction_at_d10");
    // paper predicts 86% from MPI benchmarks, measures 76% in simulations
    assert!((0.65..0.95).contains(&red), "reduction {red}");
}

#[test]
fn fig5_sync_ratio_approaches_theory() {
    let fig = run_figure("fig5", &opts()).unwrap();
    let long = get(&fig.json, "long_sync_ratio");
    assert!((long - 1.0 / 10f64.sqrt()).abs() < 0.06, "ratio {long}");
}

#[test]
fn fig6a_cv_ratio_matches_eq7() {
    let fig = run_figure("fig6a", &opts()).unwrap();
    let cv_c = get(&fig.json, "cv_conv");
    let cv_s = get(&fig.json, "cv_struct");
    assert!((cv_s / cv_c - 1.0 / 10f64.sqrt()).abs() < 1e-9);
    let cover = get(&fig.json, "maxima_tail_coverage");
    assert!((cover - 0.99).abs() < 0.01);
}

#[test]
fn fig7a_headline_reductions_in_band() {
    let fig = run_figure("fig7a", &opts()).unwrap();
    // paper at M=128: runtime -30%, deliver -25%, sync -48%, data -76%
    let runtime = get(&fig.json, "runtime_reduction_m128");
    let deliver = get(&fig.json, "deliver_reduction_m128");
    let sync = get(&fig.json, "sync_reduction_m128");
    let data = get(&fig.json, "data_reduction_m128");
    assert!((0.10..0.50).contains(&runtime), "runtime red {runtime}");
    assert!((0.05..0.50).contains(&deliver), "deliver red {deliver}");
    assert!((0.25..0.75).contains(&sync), "sync red {sync}");
    assert!((0.55..0.95).contains(&data), "data red {data}");
}

#[test]
fn fig7b_cv_ratio_between_iid_and_one() {
    let fig = run_figure("fig7b", &opts()).unwrap();
    let ratio = get(&fig.json, "cv_ratio");
    // serial correlations keep it above the iid 0.32; paper measured 0.71
    assert!(
        (0.4..0.95).contains(&ratio),
        "cv ratio {ratio} outside plausible band"
    );
}

#[test]
fn fig8c_communication_saturates_with_d() {
    let fig = run_figure("fig8c", &opts()).unwrap();
    let comm: Vec<f64> = fig
        .json
        .get("comm_rtfs")
        .and_then(Json::as_arr)
        .unwrap()
        .iter()
        .map(|x| x.as_f64().unwrap())
        .collect();
    // D = 1,2,5,10,20,50: big early gains ...
    assert!(comm[1] < comm[0]);
    assert!(comm[2] < comm[1]);
    // ... negligible beyond D=10 (less than 25% further gain)
    let late_gain = 1.0 - comm[5] / comm[3];
    let early_gain = 1.0 - comm[3] / comm[0];
    assert!(
        late_gain < 0.25 && early_gain > 0.4,
        "early {early_gain} late {late_gain}"
    );
}

#[test]
fn fig9_jureca_wins_more_than_supermuc() {
    let fig = run_figure("fig9", &opts()).unwrap();
    let ju = get(&fig.json, "speedup_jureca");
    let sm = get(&fig.json, "speedup_supermuc");
    // paper: 42% on JURECA-DC, ~parity on SuperMUC-NG
    assert!(ju > sm, "JURECA speedup {ju} !> SuperMUC {sm}");
    assert!((0.15..0.60).contains(&ju), "jureca speedup {ju}");
    assert!(sm < 0.30, "supermuc speedup {sm} too large");
}

#[test]
fn fig1b_sync_dominates_communication() {
    let fig = run_figure("fig1b", &opts()).unwrap();
    let rows = fig.json.get("rows").and_then(Json::as_arr).unwrap();
    let last = rows.last().unwrap(); // M=128
    let share = get(last, "sync_share");
    assert!(
        share > 0.5,
        "sync share at M=128 is {share}; paper: sync dominates"
    );
}
