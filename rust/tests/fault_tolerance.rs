//! Fault-tolerant runtime: checkpoint/restore round trips, the comm
//! watchdog diagnostics and the deterministic fault-injection harness.
//!
//! The invariants under test mirror `equivalence.rs`: checkpointing,
//! restoring, compute stragglers and deposit delays are *observationally
//! invisible* — bit-identical spike trains across every strategy × exec
//! × comm-mode × depth combination — while hard faults (a killed rank)
//! turn into structured, actionable errors instead of silent hangs, and
//! a `--restore` from the last snapshot reproduces the uninterrupted
//! run's train exactly.

use nsim::config::{
    CommMode, DepositDelayFault, ExecMode, KillFault, RunConfig,
    StragglerFault, Strategy,
};
use nsim::engine::checkpoint::Snapshot;
use nsim::engine::simulate;
use nsim::models;
use nsim::network::ModelSpec;
use nsim::theory::sync;
use nsim::util::timers::Phase;

/// Base config of the suite (pooled execution, blocking comm).
fn base(
    strategy: Strategy,
    m: usize,
    t: usize,
    t_model_ms: f64,
) -> RunConfig {
    RunConfig {
        strategy,
        m_ranks: m,
        threads_per_rank: t,
        t_model_ms,
        seed: 12,
        record_spikes: true,
        ..RunConfig::default()
    }
}

fn spikes(spec: &ModelSpec, cfg: &RunConfig) -> Vec<(u64, u32)> {
    simulate(spec, cfg).expect("simulation failed").spikes
}

fn err_of(spec: &ModelSpec, cfg: &RunConfig) -> String {
    match simulate(spec, cfg) {
        Err(e) => format!("{e:#}"),
        Ok(_) => panic!("expected the run to fail"),
    }
}

/// Unique-per-process snapshot path so parallel test binaries (and
/// parallel tests within one) never clobber each other's files.
fn ckpt_path(tag: &str) -> String {
    std::env::temp_dir()
        .join(format!("nsim-ft-{}-{tag}.ckpt", std::process::id()))
        .to_string_lossy()
        .into_owned()
}

#[test]
fn periodic_checkpointing_is_bit_identical_and_writes_a_snapshot() {
    let spec = models::sanity_net(240, 4).unwrap();
    // 60 ms at a 0.1 ms cycle = 600 cycles; snapshots at 250 and 500
    // (600 is not a multiple of 250, so the final state is never the
    // last snapshot and the file stays resumable)
    let reference =
        spikes(&spec, &base(Strategy::Conventional, 2, 2, 60.0));
    let path = ckpt_path("periodic");
    let ck = RunConfig {
        checkpoint_every: 250,
        checkpoint_path: path.clone(),
        ..base(Strategy::Conventional, 2, 2, 60.0)
    };
    let with_ckpt = spikes(&spec, &ck);
    assert!(reference.len() > 100, "network too quiet");
    assert_eq!(
        reference, with_ckpt,
        "periodic checkpointing changed the dynamics"
    );
    let snap = Snapshot::read_verified(&path).expect("snapshot unreadable");
    assert_eq!(snap.cycle, 500, "last periodic snapshot cycle");
    assert_eq!(snap.parts.len(), 2, "one part per rank");
    std::fs::remove_file(&path).ok();
}

#[test]
fn restore_resumes_bit_identically_across_exec_and_comm_modes() {
    let spec = models::sanity_net(240, 4).unwrap();
    let reference =
        spikes(&spec, &base(Strategy::Conventional, 2, 2, 60.0));
    let path = ckpt_path("resume-conv");
    spikes(
        &spec,
        &RunConfig {
            checkpoint_every: 250,
            checkpoint_path: path.clone(),
            ..base(Strategy::Conventional, 2, 2, 60.0)
        },
    );
    // the snapshot at cycle 500 was taken by a pooled/blocking run;
    // resuming it must be exact under *every* runtime combination —
    // the fingerprint deliberately excludes exec/comm knobs
    for exec in [
        ExecMode::Sequential,
        ExecMode::Pooled,
        ExecMode::PooledChannels,
    ] {
        for comm in [CommMode::Blocking, CommMode::Overlap] {
            let resumed = spikes(
                &spec,
                &RunConfig {
                    restore: Some(path.clone()),
                    exec,
                    comm,
                    ..base(Strategy::Conventional, 2, 2, 60.0)
                },
            );
            assert_eq!(
                resumed,
                reference,
                "restore diverged under {} / {}",
                exec.name(),
                comm.name()
            );
        }
    }
    std::fs::remove_file(&path).ok();
}

#[test]
fn restore_matches_under_structure_aware_hierarchy() {
    let spec = models::sanity_net(240, 4).unwrap();
    // structure-aware epoch = D=10 cycles = 1 ms; 60 epochs total,
    // snapshots every 25 epochs -> cycles 250 and 500
    let mk = || RunConfig {
        ranks_per_area: 2,
        ..base(Strategy::StructureAware, 4, 2, 60.0)
    };
    let reference = spikes(&spec, &mk());
    let path = ckpt_path("resume-hier");
    spikes(
        &spec,
        &RunConfig {
            checkpoint_every: 25,
            checkpoint_path: path.clone(),
            ..mk()
        },
    );
    for (exec, comm) in [
        (ExecMode::Sequential, CommMode::Blocking),
        (ExecMode::Pooled, CommMode::Blocking),
        (ExecMode::Pooled, CommMode::Overlap),
    ] {
        let resumed = spikes(
            &spec,
            &RunConfig { restore: Some(path.clone()), exec, comm, ..mk() },
        );
        assert_eq!(
            resumed,
            reference,
            "hierarchical restore diverged under {} / {}",
            exec.name(),
            comm.name()
        );
    }
    std::fs::remove_file(&path).ok();
}

#[test]
fn restore_matches_at_pipeline_depth_4() {
    // the deep-pipeline net realizes ~5 cycles of delay slack, so a
    // depth-4 split-phase pipeline is sustainable; a snapshot taken
    // *by* a depth-4 run (pipeline force-drained at the boundary) must
    // resume exactly under both blocking and depth-4 overlap
    let spec = models::deep_pipeline_net(240, 4).unwrap();
    let mk = |comm, depth| RunConfig {
        comm,
        comm_depth: depth,
        ..base(Strategy::Conventional, 2, 2, 50.0)
    };
    let reference = spikes(&spec, &mk(CommMode::Blocking, 1));
    let path = ckpt_path("resume-depth4");
    spikes(
        &spec,
        &RunConfig {
            checkpoint_every: 20,
            checkpoint_path: path.clone(),
            ..mk(CommMode::Overlap, 4)
        },
    );
    let snap = Snapshot::read_verified(&path).expect("snapshot unreadable");
    assert_eq!(snap.cycle, 40, "depth-4 snapshot cycle");
    for (comm, depth) in
        [(CommMode::Blocking, 1), (CommMode::Overlap, 4)]
    {
        let resumed = spikes(
            &spec,
            &RunConfig {
                restore: Some(path.clone()),
                ..mk(comm, depth)
            },
        );
        assert_eq!(
            resumed,
            reference,
            "depth-4 restore diverged under {} depth {depth}",
            comm.name()
        );
    }
    std::fs::remove_file(&path).ok();
}

#[test]
fn truncated_and_corrupted_snapshots_are_rejected() {
    let spec = models::sanity_net(240, 4).unwrap();
    let path = ckpt_path("corrupt");
    spikes(
        &spec,
        &RunConfig {
            checkpoint_every: 150,
            checkpoint_path: path.clone(),
            ..base(Strategy::Conventional, 2, 2, 20.0)
        },
    );
    let good = std::fs::read(&path).expect("snapshot missing");
    assert!(good.len() > 64, "snapshot implausibly small");

    // payload truncation: header survives, byte count does not
    let err = Snapshot::from_bytes(&good[..good.len() - 9])
        .expect_err("truncated snapshot accepted");
    assert!(
        format!("{err:#}").contains("truncated"),
        "unexpected truncation error: {err:#}"
    );

    // shorter than the fixed header
    let err = Snapshot::from_bytes(&good[..10])
        .expect_err("header stub accepted");
    assert!(
        format!("{err:#}").contains("shorter"),
        "unexpected header error: {err:#}"
    );

    // bad magic
    let mut bad = good.clone();
    bad[0] ^= 0xff;
    let err =
        Snapshot::from_bytes(&bad).expect_err("bad magic accepted");
    assert!(
        format!("{err:#}").contains("bad magic"),
        "unexpected magic error: {err:#}"
    );

    // a flipped payload byte must fail the checksum, end to end
    // through the engine's --restore path
    let mut bad = good.clone();
    bad[40] ^= 0xff;
    let bad_path = ckpt_path("corrupt-flipped");
    std::fs::write(&bad_path, &bad).unwrap();
    let msg = err_of(
        &spec,
        &RunConfig {
            restore: Some(bad_path.clone()),
            ..base(Strategy::Conventional, 2, 2, 20.0)
        },
    );
    assert!(
        msg.contains("checksum"),
        "corruption not reported as a checksum mismatch: {msg}"
    );
    std::fs::remove_file(&path).ok();
    std::fs::remove_file(&bad_path).ok();
}

#[test]
fn restore_under_a_different_shape_names_the_offending_flag() {
    let spec = models::sanity_net(240, 4).unwrap();
    let path = ckpt_path("shape");
    spikes(
        &spec,
        &RunConfig {
            checkpoint_every: 150,
            checkpoint_path: path.clone(),
            ..base(Strategy::Conventional, 2, 2, 20.0)
        },
    );
    // different --threads: rejected explicitly, not garbled state
    let msg = err_of(
        &spec,
        &RunConfig {
            restore: Some(path.clone()),
            ..base(Strategy::Conventional, 2, 4, 20.0)
        },
    );
    assert!(
        msg.contains("--threads"),
        "thread-count mismatch not named: {msg}"
    );
    // different --seed: the snapshot encodes the RNG state implicitly
    // (all jitter is seed-keyed), so a seed mismatch is a hard error
    let msg = err_of(
        &spec,
        &RunConfig {
            restore: Some(path.clone()),
            seed: 13,
            ..base(Strategy::Conventional, 2, 2, 20.0)
        },
    );
    assert!(msg.contains("--seed"), "seed mismatch not named: {msg}");
    std::fs::remove_file(&path).ok();
}

#[test]
fn kill_then_restore_reproduces_the_reference_train() {
    let spec = models::sanity_net(240, 4).unwrap();
    let reference =
        spikes(&spec, &base(Strategy::Conventional, 2, 2, 60.0));
    let path = ckpt_path("kill-restore");

    // rank 1 dies at epoch 400, right after the cycle-400 snapshot
    // (the killed rank checkpoints first, dies after); rank 0 then
    // hits the watchdog on the next exchange
    let mut failing = RunConfig {
        checkpoint_every: 200,
        checkpoint_path: path.clone(),
        comm_timeout: Some(0.5),
        ..base(Strategy::Conventional, 2, 2, 60.0)
    };
    failing.faults.kills.push(KillFault { rank: 1, epoch: 400 });
    let msg = err_of(&spec, &failing);
    assert!(
        msg.contains("comm watchdog") || msg.contains("fault injection"),
        "dead rank produced an unstructured error: {msg}"
    );

    // the crash left a valid snapshot at the kill cycle
    let snap = Snapshot::read_verified(&path)
        .expect("no snapshot survived the crash");
    assert_eq!(snap.cycle, 400, "snapshot cycle at the kill point");

    // resuming it reproduces the uninterrupted train bit-exactly
    let resumed = spikes(
        &spec,
        &RunConfig {
            restore: Some(path.clone()),
            ..base(Strategy::Conventional, 2, 2, 60.0)
        },
    );
    assert_eq!(
        resumed, reference,
        "restore after the kill diverged from the reference train"
    );
    std::fs::remove_file(&path).ok();
}

#[test]
fn dead_rank_trips_the_watchdog_with_a_structured_diagnostic() {
    let spec = models::sanity_net(240, 4).unwrap();
    // rank 1 dies at epoch 1; rank 0's next exchange must expire into
    // the watchdog diagnostic naming the tier and the missing rank
    let mut cfg = RunConfig {
        comm_timeout: Some(0.5),
        ..base(Strategy::Conventional, 2, 2, 10.0)
    };
    cfg.faults.kills.push(KillFault { rank: 1, epoch: 1 });
    let msg = err_of(&spec, &cfg);
    assert!(
        msg.contains("comm watchdog"),
        "watchdog did not fire: {msg}"
    );
    assert!(
        msg.contains("global tier"),
        "stalled tier not named: {msg}"
    );
    assert!(
        msg.contains("missing ranks [1]"),
        "missing rank not named: {msg}"
    );
}

#[test]
fn killed_rank_itself_reports_the_injected_fault() {
    let spec = models::sanity_net(240, 4).unwrap();
    // killing rank 0 makes *its* error the first in rank order: the
    // injection bail, not a peer's watchdog report
    let mut cfg = RunConfig {
        comm_timeout: Some(0.5),
        ..base(Strategy::Conventional, 2, 2, 10.0)
    };
    cfg.faults.kills.push(KillFault { rank: 0, epoch: 1 });
    let msg = err_of(&spec, &cfg);
    assert!(
        msg.contains("fault injection") && msg.contains("killed at epoch 1"),
        "kill fault not reported by the dying rank: {msg}"
    );
}

#[test]
fn stragglers_and_deposit_delays_do_not_change_dynamics() {
    // the depth-4 pipeline on the deep net is the paper's absorption
    // scenario: a compute straggler inflates one rank's update phase,
    // the in-flight window hides (part of) the skew, and the spike
    // train is untouched — the prediction `predicted_depth_gain` makes
    let spec = models::deep_pipeline_net(240, 4).unwrap();
    let mk = || RunConfig {
        comm: CommMode::Overlap,
        comm_depth: 4,
        comm_timeout: Some(5.0),
        ..base(Strategy::Conventional, 2, 2, 50.0)
    };
    let baseline = simulate(&spec, &mk()).expect("baseline failed");

    let mut cfg = mk();
    cfg.faults.stragglers.push(StragglerFault {
        rank: 0,
        factor: 5.0,
        from_epoch: 0,
        to_epoch: 25,
    });
    cfg.faults.deposit_delays.push(DepositDelayFault {
        rank: 1,
        delay_ms: 1.0,
        from_epoch: 0,
        to_epoch: 25,
    });
    let faulty = simulate(&spec, &cfg).expect("fault-injected run failed");

    assert!(!baseline.spikes.is_empty(), "network too quiet");
    assert_eq!(
        baseline.spikes, faulty.spikes,
        "timing-only faults changed the spike train"
    );
    assert_eq!(
        faulty.comm_stats.timeouts, 0,
        "faults within the watchdog budget must not time out"
    );
    // the injected inflation is visible where it should be: in the
    // straggling rank's update phase, not in anyone's spike train
    let upd = |r: usize| faulty.rank_times[r].get(Phase::Update);
    assert!(
        upd(0) > upd(1),
        "straggler's update time ({}) not above its peer's ({})",
        upd(0),
        upd(1)
    );
    // and the paper's model predicts a depth-D pipeline absorbs a
    // strictly positive amount of the induced skew, growing with depth
    let model = sync::CycleTimeModel::paper_default();
    let g2 = sync::predicted_depth_gain(model, 2, 50, 1, 2, 4);
    let g4 = sync::predicted_depth_gain(model, 2, 50, 1, 4, 4);
    assert!(
        g2 > 0.0 && g4 >= g2,
        "depth gain not positive/monotone: depth2 {g2}, depth4 {g4}"
    );
}

#[test]
fn checkpoint_write_failure_surfaces_on_every_rank() {
    let spec = models::sanity_net(240, 4).unwrap();
    let dir = std::env::temp_dir()
        .join(format!("nsim-ft-{}-missing-dir", std::process::id()));
    let path = dir.join("x.ckpt").to_string_lossy().into_owned();
    let msg = err_of(
        &spec,
        &RunConfig {
            checkpoint_every: 100,
            checkpoint_path: path,
            ..base(Strategy::Conventional, 2, 2, 20.0)
        },
    );
    assert!(
        msg.contains("checkpoint write failed"),
        "unwritable checkpoint path not reported: {msg}"
    );
}
