//! Cross-process equivalence: `nsim launch` (one OS process per rank
//! over the Unix-domain-socket transport) must reproduce the in-process
//! shared-memory engine bit-identically — same model, same seed, same
//! `(step, gid)` spike train — across comm mode × depth × hierarchical
//! splitting.  Plus the failure side: a killed rank process turns into a
//! nonzero launcher exit with the watchdog naming the dead rank, never a
//! hang.
//!
//! These tests spawn the real `nsim` binary (`CARGO_BIN_EXE_nsim`), so
//! they exercise the whole stack: CLI parsing, the socket rendezvous,
//! the wire protocol, per-rank spike files and the launcher's merge.

#![cfg(unix)]

use std::process::Command;

use nsim::config::{CommMode, RunConfig, Strategy};
use nsim::engine::simulate;
use nsim::models;

fn nsim_bin() -> &'static str {
    env!("CARGO_BIN_EXE_nsim")
}

fn tmp_path(tag: &str) -> String {
    std::env::temp_dir()
        .join(format!("nsim-mp-{}-{tag}.spikes", std::process::id()))
        .to_string_lossy()
        .into_owned()
}

fn read_spikes(path: &str) -> Vec<(u64, u32)> {
    let text = std::fs::read_to_string(path)
        .unwrap_or_else(|e| panic!("reading {path}: {e}"));
    text.lines()
        .map(|line| {
            let mut it = line.split_whitespace();
            let step = it.next().unwrap().parse().unwrap();
            let gid = it.next().unwrap().parse().unwrap();
            (step, gid)
        })
        .collect()
}

/// Run `nsim launch --ranks M <extra>` and return the merged spike
/// train.  The launcher inherits its children's stdio, so any rank's
/// diagnostics surface in the captured output on failure.
fn launch_spikes(ranks: usize, tag: &str, extra: &[&str]) -> Vec<(u64, u32)> {
    let out_path = tmp_path(tag);
    let output = Command::new(nsim_bin())
        .arg("launch")
        .args(["--ranks", &ranks.to_string()])
        .args(extra)
        .args(["--spikes-out", &out_path])
        .output()
        .expect("running nsim launch");
    assert!(
        output.status.success(),
        "launch failed ({}):\n--- stdout ---\n{}\n--- stderr ---\n{}",
        output.status,
        String::from_utf8_lossy(&output.stdout),
        String::from_utf8_lossy(&output.stderr),
    );
    let spikes = read_spikes(&out_path);
    let _ = std::fs::remove_file(&out_path);
    spikes
}

#[test]
fn socket_matches_inprocess_blocking_conventional() {
    let spec = models::sanity_net(240, 4).unwrap();
    let cfg = RunConfig {
        strategy: Strategy::Conventional,
        m_ranks: 4,
        threads_per_rank: 2,
        t_model_ms: 100.0,
        seed: 12,
        record_spikes: true,
        ..RunConfig::default()
    };
    let want = simulate(&spec, &cfg).expect("in-process run").spikes;
    assert!(
        want.len() > 100,
        "network too quiet for a meaningful test: {} spikes",
        want.len()
    );
    let got = launch_spikes(4, "conv", &[
        "--model", "sanity", "--n-per-area", "240", "--areas", "4",
        "--strategy", "conventional", "--threads", "2",
        "--t-model", "100", "--seed", "12",
    ]);
    assert_eq!(want, got, "socket run diverged from in-process run");
}

#[test]
fn socket_matches_inprocess_overlap_depth2() {
    let spec = models::deep_pipeline_net(240, 4).unwrap();
    let cfg = RunConfig {
        strategy: Strategy::StructureAware,
        m_ranks: 4,
        threads_per_rank: 1,
        t_model_ms: 100.0,
        seed: 12,
        comm: CommMode::Overlap,
        comm_depth: 2,
        record_spikes: true,
        ..RunConfig::default()
    };
    let want = simulate(&spec, &cfg).expect("in-process run").spikes;
    assert!(
        want.len() > 100,
        "network too quiet for a meaningful test: {} spikes",
        want.len()
    );
    let got = launch_spikes(4, "overlap", &[
        "--model", "deep-pipeline", "--n-per-area", "240", "--areas",
        "4", "--strategy", "structure-aware", "--threads", "1",
        "--comm", "overlap", "--comm-depth", "2",
        "--t-model", "100", "--seed", "12",
    ]);
    assert_eq!(want, got, "socket run diverged from in-process run");
}

#[test]
fn socket_matches_inprocess_hierarchical_split() {
    // 4 areas x 2-rank groups on 8 ranks: the dual-pathway split gives
    // every process a global and a local socket sub-communicator
    let spec = models::deep_pipeline_net(240, 4).unwrap();
    let cfg = RunConfig {
        strategy: Strategy::StructureAware,
        m_ranks: 8,
        threads_per_rank: 1,
        ranks_per_area: 2,
        t_model_ms: 100.0,
        seed: 12,
        record_spikes: true,
        ..RunConfig::default()
    };
    let want = simulate(&spec, &cfg).expect("in-process run").spikes;
    assert!(
        want.len() > 100,
        "network too quiet for a meaningful test: {} spikes",
        want.len()
    );
    let got = launch_spikes(8, "hier", &[
        "--model", "deep-pipeline", "--n-per-area", "240", "--areas",
        "4", "--strategy", "structure-aware", "--threads", "1",
        "--ranks-per-area", "2", "--t-model", "100", "--seed", "12",
    ]);
    assert_eq!(want, got, "socket run diverged from in-process run");
}

#[test]
fn launch_kill_at_fails_with_watchdog_naming_dead_rank() {
    let out_path = tmp_path("kill");
    let output = Command::new(nsim_bin())
        .arg("launch")
        .args(["--ranks", "2"])
        .args([
            "--model", "sanity", "--n-per-area", "120", "--areas", "2",
            "--threads", "1", "--t-model", "100", "--seed", "12",
            "--kill-at", "1:1", "--comm-timeout", "2",
        ])
        .args(["--spikes-out", &out_path])
        .output()
        .expect("running nsim launch");
    let _ = std::fs::remove_file(&out_path);
    assert!(
        !output.status.success(),
        "a killed rank must fail the launch"
    );
    let all = format!(
        "{}{}",
        String::from_utf8_lossy(&output.stdout),
        String::from_utf8_lossy(&output.stderr),
    );
    // the killed rank reports its own injected fault...
    assert!(
        all.contains("fault injection: rank 1 killed"),
        "missing the killed rank's diagnostic:\n{all}"
    );
    // ...and the survivor's watchdog names the dead rank instead of
    // hanging on it
    assert!(
        all.contains("comm watchdog: rank 0 timed out"),
        "missing the survivor's watchdog diagnostic:\n{all}"
    );
    assert!(
        all.contains("missing ranks [1]"),
        "watchdog does not name the dead rank:\n{all}"
    );
    // the launcher itself points at the failing rank processes
    assert!(
        all.contains("launch: rank 1 failed")
            && all.contains("launch: rank 0 failed"),
        "launcher did not attribute the failures:\n{all}"
    );
}
