//! End-to-end tests of the serving layer: `nsim serve`'s job server
//! must be a *layer over* the engine, not a fork of it — the spike
//! train a job streams back is byte-identical to the direct
//! `nsim simulate` run of the same config, for every catalog scenario,
//! including with jobs running concurrently.  Plus the lifecycle side:
//! cancellation mid-run frees the worker slot and reports `cancelled`,
//! malformed submissions get typed error frames (never a dead
//! connection), per-job timeouts fail the job, a kill-injected job
//! resumes from its checkpoint, and per-job `--stats-json`/`--trace`
//! outputs land under deterministic `job-<n>` suffixes.

#![cfg(unix)]

use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::path::PathBuf;
use std::process::Command;
use std::time::{Duration, Instant};

use nsim::engine;
use nsim::serve::{start, Catalog, Client, ServeOpts, ServerHandle};
use nsim::util::json::{self, Json};

fn nsim_bin() -> &'static str {
    env!("CARGO_BIN_EXE_nsim")
}

/// Unique scratch path under the system temp dir.
fn tmp_path(tag: &str) -> PathBuf {
    static NEXT: std::sync::atomic::AtomicU64 =
        std::sync::atomic::AtomicU64::new(0);
    let n = NEXT.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
    std::env::temp_dir().join(format!(
        "nsim-serve-{}-{n}-{tag}",
        std::process::id()
    ))
}

/// A server on a fresh socket with its own scratch workdir.
fn start_server(tag: &str, configure: impl FnOnce(&mut ServeOpts)) -> (ServerHandle, PathBuf) {
    let socket = tmp_path(&format!("{tag}.sock"));
    let mut opts = ServeOpts::new(&socket);
    opts.workdir = tmp_path(&format!("{tag}.work"));
    configure(&mut opts);
    let handle = start(opts).expect("starting job server");
    (handle, socket)
}

/// The reference result: instantiate the scenario exactly as the server
/// does and run it through the plain engine, formatting the spike train
/// with the canonical `"{step} {gid}\n"` lines `--spikes-out` writes.
fn reference_spikes_text(
    scenario: &str,
    params: &BTreeMap<String, Json>,
) -> String {
    let cat = Catalog::builtin();
    let s = cat.get(scenario).expect("scenario in builtin catalog");
    let (spec, cfg, _) = s.instantiate(params).expect("instantiate");
    let res = engine::simulate(&spec, &cfg).expect("reference run");
    let mut text = String::with_capacity(res.spikes.len() * 12);
    for &(step, gid) in &res.spikes {
        let _ = writeln!(text, "{step} {gid}");
    }
    text
}

fn p(entries: &[(&str, Json)]) -> BTreeMap<String, Json> {
    entries
        .iter()
        .map(|(k, v)| (k.to_string(), v.clone()))
        .collect()
}

/// Submit with follow and return every job's terminal outcome.
fn submit_and_follow(
    socket: &PathBuf,
    scenario: &str,
    params: &BTreeMap<String, Json>,
    sweep: &BTreeMap<String, Json>,
) -> (Vec<nsim::serve::client::JobEnd>, Vec<Json>) {
    let mut client = Client::connect(socket).expect("connect");
    client
        .submit(scenario, params, sweep, true)
        .expect("submit");
    let mut events = Vec::new();
    let ends = client
        .follow_until_complete(|ev| events.push(ev.clone()))
        .expect("follow");
    (ends, events)
}

fn shutdown(handle: ServerHandle) {
    handle.shutdown();
    handle.join();
}

// ---------------------------------------------------------------------
// equivalence: serving is a layer over the engine

/// For every builtin catalog scenario, the spike train streamed through
/// `serve`/`submit` is byte-identical to the direct run of the same
/// config.  Params shrink each scenario so debug-mode CI stays fast —
/// the shrink goes through the same parameter routing a user's would.
#[test]
fn every_catalog_scenario_streams_identical_to_direct_run() {
    let shrink: &[(&str, BTreeMap<String, Json>)] = &[
        ("mam-ground-state", p(&[("t_model_ms", Json::Num(5.0))])),
        (
            "deliver-heavy",
            p(&[
                ("n_per_area", Json::Num(150.0)),
                ("t_model_ms", Json::Num(10.0)),
            ]),
        ),
        (
            "deep-pipeline",
            p(&[
                ("n_per_area", Json::Num(120.0)),
                ("t_model_ms", Json::Num(10.0)),
            ]),
        ),
        ("mam-lesion-v1", p(&[("t_model_ms", Json::Num(10.0))])),
    ];
    let cat = Catalog::builtin();
    assert_eq!(
        cat.names().len(),
        shrink.len(),
        "new builtin scenario? cover it here"
    );

    let (handle, socket) = start_server("every", |_| {});
    for (scenario, params) in shrink {
        let (ends, events) =
            submit_and_follow(&socket, scenario, params, &BTreeMap::new());
        assert_eq!(ends.len(), 1, "{scenario}");
        let end = &ends[0];
        assert_eq!(end.state, "done", "{scenario}: {:?}", end.error);
        let want = reference_spikes_text(scenario, params);
        assert!(!want.is_empty(), "{scenario}: silent reference net");
        assert_eq!(
            end.spikes.as_deref(),
            Some(want.as_str()),
            "{scenario}: streamed train differs from the direct run"
        );
        // the stats document is the nsim-stats-v1 report with the job
        // id stamped into the config block
        let stats = end.stats.as_ref().expect("stats document");
        assert_eq!(
            stats.get("schema").and_then(Json::as_str),
            Some("nsim-stats-v1")
        );
        assert_eq!(
            stats
                .get("config")
                .and_then(|c| c.get("job"))
                .and_then(Json::as_str),
            Some(end.job.as_str())
        );
        // periodic progress frames arrived while the job ran
        let progressed = events.iter().any(|ev| {
            ev.get("event").and_then(Json::as_str) == Some("progress")
                && ev.get("job").and_then(Json::as_str)
                    == Some(end.job.as_str())
        });
        assert!(progressed, "{scenario}: no progress frames streamed");
    }
    shutdown(handle);
}

/// The streamed result is byte-identical to what the *actual CLI*
/// writes with `--spikes-out` — the same bytes `cmp` checks in the CI
/// `serve-smoke` job.
#[test]
fn streamed_result_matches_direct_cli_run() {
    let params = p(&[
        ("n_per_area", Json::Num(150.0)),
        ("t_model_ms", Json::Num(10.0)),
    ]);
    let (handle, socket) = start_server("cli", |_| {});
    let (ends, _) = submit_and_follow(
        &socket,
        "deliver-heavy",
        &params,
        &BTreeMap::new(),
    );
    shutdown(handle);
    assert_eq!(ends[0].state, "done", "{:?}", ends[0].error);
    let streamed = ends[0].spikes.clone().expect("spike train");

    let out_path = tmp_path("cli.spikes");
    let output = Command::new(nsim_bin())
        .args(["simulate", "--model", "sanity"])
        .args(["--n-per-area", "150", "--areas", "4"])
        .args(["--strategy", "conventional"])
        .args(["--ranks", "2", "--threads", "2"])
        .args(["--t-model", "10", "--seed", "12"])
        .args(["--spikes-out", &out_path.to_string_lossy()])
        .output()
        .expect("running nsim simulate");
    assert!(
        output.status.success(),
        "direct CLI run failed:\n{}",
        String::from_utf8_lossy(&output.stderr)
    );
    let direct = std::fs::read_to_string(&out_path).expect("spike file");
    let _ = std::fs::remove_file(&out_path);
    assert_eq!(streamed, direct, "streamed bytes != direct CLI bytes");
}

/// Two jobs running concurrently (2 workers, submitted as one sweep)
/// stream the same trains their solo runs produce — no interleaving,
/// no cross-job perturbation.
#[test]
fn concurrent_jobs_are_bit_identical_to_solo_runs() {
    let base = p(&[("n_per_area", Json::Num(150.0))]);
    // sweep over t_model: two jobs with distinct references, claimed by
    // the two workers at the same time
    let sweep = p(&[(
        "t_model_ms",
        Json::Arr(vec![Json::Num(10.0), Json::Num(15.0)]),
    )]);
    let (handle, socket) = start_server("conc", |o| o.workers = 2);
    let (ends, _) =
        submit_and_follow(&socket, "deliver-heavy", &base, &sweep);
    shutdown(handle);
    assert_eq!(ends.len(), 2);
    for (end, t_model) in ends.iter().zip([10.0, 15.0]) {
        assert_eq!(end.state, "done", "{}: {:?}", end.job, end.error);
        let mut params = base.clone();
        params.insert("t_model_ms".to_string(), Json::Num(t_model));
        let want = reference_spikes_text("deliver-heavy", &params);
        assert_eq!(
            end.spikes.as_deref(),
            Some(want.as_str()),
            "{}: concurrent train differs from solo run",
            end.job
        );
    }
}

// ---------------------------------------------------------------------
// lifecycle: cancellation, timeouts, typed rejections, resume

/// Cancelling a running job reports `cancelled` and frees the worker
/// slot: a follow-up job on the same single-worker server completes.
#[test]
fn cancellation_mid_run_frees_the_worker_slot() {
    let (handle, socket) = start_server("cancel", |o| o.workers = 1);
    let mut submitter = Client::connect(&socket).expect("connect");
    // long enough that the cancel lands mid-run (cancellation is
    // checked at every epoch boundary)
    let long = p(&[
        ("n_per_area", Json::Num(150.0)),
        ("t_model_ms", Json::Num(60000.0)),
    ]);
    let ids = submitter
        .submit("deliver-heavy", &long, &BTreeMap::new(), false)
        .expect("submit");
    let id = ids[0].clone();

    let mut ctl = Client::connect(&socket).expect("connect");
    let deadline = Instant::now() + Duration::from_secs(60);
    loop {
        let st = ctl.status(&id).expect("status");
        match st.get("state").and_then(Json::as_str) {
            Some("running") => break,
            Some("done") | Some("failed") | Some("cancelled") => {
                panic!("job went terminal before cancel: {st:?}")
            }
            _ => {}
        }
        assert!(Instant::now() < deadline, "job never started running");
        std::thread::sleep(Duration::from_millis(20));
    }
    let resp = ctl.cancel(&id).expect("cancel");
    assert_eq!(resp.get("was").and_then(Json::as_str), Some("running"));

    let deadline = Instant::now() + Duration::from_secs(60);
    loop {
        let st = ctl.status(&id).expect("status");
        let state = st.get("state").and_then(Json::as_str);
        if state == Some("cancelled") {
            break;
        }
        assert_ne!(state, Some("done"), "cancelled job reported done");
        assert_ne!(
            state,
            Some("failed"),
            "cancelled job reported failed: {st:?}"
        );
        assert!(Instant::now() < deadline, "cancel never landed");
        std::thread::sleep(Duration::from_millis(20));
    }

    // the single worker is free again: a small job completes
    let small = p(&[
        ("n_per_area", Json::Num(120.0)),
        ("t_model_ms", Json::Num(5.0)),
    ]);
    let (ends, _) = submit_and_follow(
        &socket,
        "deliver-heavy",
        &small,
        &BTreeMap::new(),
    );
    assert_eq!(ends[0].state, "done", "{:?}", ends[0].error);
    shutdown(handle);
}

/// A job past its `timeout_secs` wall-clock deadline fails (with the
/// timeout named), it does not report `cancelled`.
#[test]
fn job_timeout_fails_the_job() {
    let (handle, socket) = start_server("timeout", |_| {});
    let params = p(&[
        ("n_per_area", Json::Num(150.0)),
        ("t_model_ms", Json::Num(60000.0)),
        ("timeout_secs", Json::Num(0.2)),
    ]);
    let (ends, _) = submit_and_follow(
        &socket,
        "deliver-heavy",
        &params,
        &BTreeMap::new(),
    );
    shutdown(handle);
    assert_eq!(ends[0].state, "failed");
    let err = ends[0].error.as_deref().unwrap_or_default();
    assert!(err.contains("timeout"), "error must name the timeout: {err}");
}

/// Malformed frames and bad submissions are typed error frames, never a
/// dead connection: after a rejected op the same connection keeps
/// serving.
#[test]
fn malformed_jobs_are_rejected_with_typed_errors() {
    let (handle, socket) = start_server("reject", |_| {});
    let mut client = Client::connect(&socket).expect("connect");

    // unknown scenario: typed unknown-scenario naming the catalog
    let err = client
        .submit("no-such-net", &BTreeMap::new(), &BTreeMap::new(), false)
        .unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("unknown-scenario"), "{msg}");
    assert!(msg.contains("deliver-heavy"), "must list the catalog: {msg}");

    // bad params: typed bad-params before anything is enqueued
    let err = client
        .submit(
            "deliver-heavy",
            &p(&[("warp_factor", Json::Num(9.0))]),
            &BTreeMap::new(),
            false,
        )
        .unwrap_err();
    assert!(format!("{err:#}").contains("bad-params"), "{err:#}");

    // a bad sweep grid point rejects the whole submission atomically
    let err = client
        .submit(
            "deliver-heavy",
            &BTreeMap::new(),
            &p(&[("lesion_factor", Json::Arr(vec![Json::Num(0.3)]))]),
            false,
        )
        .unwrap_err();
    assert!(format!("{err:#}").contains("bad-params"), "{err:#}");
    let jobs = client.jobs().expect("jobs");
    assert_eq!(
        jobs.as_arr().map(Vec::len),
        Some(0),
        "rejected submissions must enqueue nothing"
    );

    // ops on unknown jobs: typed unknown-job
    let err = client.status("job-99").unwrap_err();
    assert!(format!("{err:#}").contains("unknown-job"), "{err:#}");
    let err = client.cancel("job-99").unwrap_err();
    assert!(format!("{err:#}").contains("unknown-job"), "{err:#}");

    // a request that is not even an object: typed bad-request, and the
    // connection still answers a ping afterwards
    let resp = client.request(&Json::Num(42.0)).unwrap_err();
    assert!(format!("{resp:#}").contains("bad-request"), "{resp:#}");
    client.ping().expect("connection must survive rejections");

    // raw garbage that parses as no JSON at all: an error frame comes
    // back before the server hangs up (torn framing cannot recover)
    use std::io::{Read, Write};
    let mut raw =
        std::os::unix::net::UnixStream::connect(&socket).expect("raw");
    let garbage = b"not json";
    raw.write_all(&(garbage.len() as u32).to_le_bytes()).unwrap();
    raw.write_all(garbage).unwrap();
    let mut hdr = [0u8; 4];
    raw.read_exact(&mut hdr).expect("typed error frame, not EOF");
    let len = u32::from_le_bytes(hdr) as usize;
    let mut payload = vec![0u8; len];
    raw.read_exact(&mut payload).unwrap();
    let v = json::parse(std::str::from_utf8(&payload).unwrap()).unwrap();
    assert_eq!(v.get("ok").and_then(Json::as_bool), Some(false));
    assert_eq!(
        v.get("kind").and_then(Json::as_str),
        Some("bad-request")
    );
    shutdown(handle);
}

/// A job killed by the existing `--kill-at` fault plan restarts from
/// its checkpoint (one `resume` event) and completes with the reference
/// train of an uninterrupted run.
#[test]
fn killed_job_resumes_from_checkpoint_with_reference_train() {
    let (handle, socket) = start_server("resume", |_| {});
    let faulty = p(&[
        ("n_per_area", Json::Num(150.0)),
        ("t_model_ms", Json::Num(40.0)),
        ("kill_at", Json::Str("1:2".to_string())),
        ("comm_timeout", Json::Num(5.0)),
        ("checkpoint_every", Json::Num(1.0)),
    ]);
    let (ends, events) = submit_and_follow(
        &socket,
        "deliver-heavy",
        &faulty,
        &BTreeMap::new(),
    );
    shutdown(handle);
    assert_eq!(ends[0].state, "done", "{:?}", ends[0].error);
    let resumed = events.iter().any(|ev| {
        ev.get("event").and_then(Json::as_str) == Some("resume")
    });
    assert!(resumed, "no resume event — did the kill fire?");

    // reference: the same config without the fault or checkpointing
    let clean = p(&[
        ("n_per_area", Json::Num(150.0)),
        ("t_model_ms", Json::Num(40.0)),
    ]);
    let want = reference_spikes_text("deliver-heavy", &clean);
    assert_eq!(
        ends[0].spikes.as_deref(),
        Some(want.as_str()),
        "resumed train differs from the uninterrupted run"
    );
}

// ---------------------------------------------------------------------
// per-job outputs and the catalog CLI

/// Per-job stats/trace outputs land under deterministic `job-<n>`
/// suffixes (the server-side analogue of `nsim launch`'s `.rank<r>`),
/// with `config.job` stamped into each stats document.
#[test]
fn per_job_outputs_get_job_suffixes() {
    let stats_base = tmp_path("stats.json");
    let trace_base = tmp_path("trace.json");
    let (handle, socket) = start_server("outputs", |o| {
        o.workers = 1;
        o.stats_base = Some(stats_base.to_string_lossy().into_owned());
        o.trace_base = Some(trace_base.to_string_lossy().into_owned());
    });
    let params = p(&[
        ("n_per_area", Json::Num(120.0)),
        ("t_model_ms", Json::Num(5.0)),
    ]);
    let sweep = p(&[(
        "seed",
        Json::Arr(vec![Json::Num(1.0), Json::Num(2.0)]),
    )]);
    let (ends, _) =
        submit_and_follow(&socket, "deliver-heavy", &params, &sweep);
    shutdown(handle);
    assert_eq!(ends.len(), 2);
    for (end, n) in ends.iter().zip(0..) {
        assert_eq!(end.state, "done", "{:?}", end.error);
        assert_eq!(end.job, format!("job-{n}"), "deterministic ids");
        let stats_path =
            format!("{}.job-{n}", stats_base.to_string_lossy());
        let text = std::fs::read_to_string(&stats_path)
            .unwrap_or_else(|e| panic!("reading {stats_path}: {e}"));
        let doc = json::parse(&text).expect("stats JSON");
        assert_eq!(
            doc.get("config")
                .and_then(|c| c.get("job"))
                .and_then(Json::as_str),
            Some(format!("job-{n}").as_str())
        );
        let trace_path =
            format!("{}.job-{n}", trace_base.to_string_lossy());
        let text = std::fs::read_to_string(&trace_path)
            .unwrap_or_else(|e| panic!("reading {trace_path}: {e}"));
        let doc = json::parse(&text).expect("trace JSON");
        assert!(
            doc.get("traceEvents")
                .and_then(Json::as_arr)
                .is_some_and(|evs| !evs.is_empty()),
            "trace must carry spans"
        );
        let _ = std::fs::remove_file(&stats_path);
        let _ = std::fs::remove_file(&trace_path);
    }
}

/// `nsim scenarios` lists the built-in catalog, overlays `--dir` files
/// by name, and `--json` emits the machine-readable catalog.
#[test]
fn scenarios_cli_lists_builtins_and_overlays() {
    let dir = tmp_path("cat");
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(
        dir.join("custom.json"),
        r#"{"name": "custom-net",
            "description": "an overlay scenario",
            "model": {"kind": "sanity", "n_per_area": 64},
            "config": {"t_model_ms": 5.0}}"#,
    )
    .unwrap();
    let output = Command::new(nsim_bin())
        .args(["scenarios", "--dir", &dir.to_string_lossy()])
        .output()
        .expect("running nsim scenarios");
    assert!(output.status.success());
    let text = String::from_utf8_lossy(&output.stdout);
    for name in [
        "mam-ground-state",
        "deliver-heavy",
        "deep-pipeline",
        "mam-lesion-v1",
        "custom-net",
    ] {
        assert!(text.contains(name), "listing misses {name}:\n{text}");
    }
    let output = Command::new(nsim_bin())
        .args(["scenarios", "--dir", &dir.to_string_lossy(), "--json"])
        .output()
        .expect("running nsim scenarios --json");
    assert!(output.status.success());
    let doc =
        json::parse(&String::from_utf8_lossy(&output.stdout)).unwrap();
    assert!(doc.as_arr().is_some_and(|a| a.len() == 5));
    let _ = std::fs::remove_dir_all(&dir);
}
