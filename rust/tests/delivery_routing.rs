//! Edge cases of the thread-sharded spike delivery and determinism of
//! the persistent barrier worker runtime (`engine::rank`).
//!
//! The routing layer fans each received spike batch into per-thread
//! queues once, so correctness hinges on: empty batches being no-ops,
//! spikes from sources without local connections being dropped cleanly,
//! threads that own few (or zero) neurons staying in lock-step at the
//! phase barriers, and repeated runs of the same configuration being
//! bit-deterministic.

use nsim::config::{ExecMode, RunConfig, Strategy};
use nsim::engine::simulate;
use nsim::models;
use nsim::network::ModelSpec;

fn run_exec(
    spec: &ModelSpec,
    strategy: Strategy,
    m: usize,
    t: usize,
    t_model_ms: f64,
    exec: ExecMode,
) -> Vec<(u64, u32)> {
    let cfg = RunConfig {
        strategy,
        m_ranks: m,
        threads_per_rank: t,
        t_model_ms,
        seed: 12,
        exec,
        record_spikes: true,
        ..RunConfig::default()
    };
    simulate(spec, &cfg).expect("simulation failed").spikes
}

#[test]
fn empty_batches_are_noops() {
    // ignore-and-fire at 2.5 Hz leaves most cycles without any spikes:
    // the deliver phase must route empty batches through the barrier
    // protocol without stalling or corrupting state
    let spec = models::mam_benchmark(4, 0.004, 1.0).unwrap();
    for strategy in [Strategy::Conventional, Strategy::StructureAware] {
        let seq = run_exec(&spec, strategy, 4, 3, 20.0, ExecMode::Sequential);
        let bar = run_exec(&spec, strategy, 4, 3, 20.0, ExecMode::Pooled);
        assert_eq!(seq, bar, "{}: empty-batch cycles diverged", strategy.name());
    }
}

#[test]
fn first_cycle_with_no_received_spikes() {
    // the very first deliver of every run sees empty receive buffers; a
    // single-cycle run exercises exactly that path
    let spec = models::sanity_net(120, 2).unwrap();
    let one_cycle_ms = 2.0; // a handful of cycles at most
    let seq = run_exec(
        &spec,
        Strategy::Conventional,
        2,
        4,
        one_cycle_ms,
        ExecMode::Sequential,
    );
    let bar = run_exec(
        &spec,
        Strategy::Conventional,
        2,
        4,
        one_cycle_ms,
        ExecMode::Pooled,
    );
    assert_eq!(seq, bar);
}

#[test]
fn sources_without_local_targets_are_dropped_cleanly() {
    // round-robin placement scatters connectivity so each rank receives
    // spikes whose sources connect to only a subset of its threads; the
    // sharded router must drop the rest without observable effect
    let spec = models::sanity_net(150, 3).unwrap();
    let seq =
        run_exec(&spec, Strategy::Conventional, 3, 3, 100.0, ExecMode::Sequential);
    assert!(seq.len() > 100, "too quiet to be meaningful");
    let bar =
        run_exec(&spec, Strategy::Conventional, 3, 3, 100.0, ExecMode::Pooled);
    assert_eq!(seq, bar);
}

#[test]
fn more_threads_than_spiking_neurons() {
    // 12 neurons over 2 ranks x 8 threads: most threads host one neuron,
    // some host none — every thread must still participate in all phase
    // barriers every cycle
    let spec = models::sanity_net(6, 2).unwrap();
    for exec in [ExecMode::Pooled, ExecMode::PooledChannels] {
        let seq = run_exec(
            &spec,
            Strategy::Conventional,
            2,
            8,
            50.0,
            ExecMode::Sequential,
        );
        let par = run_exec(&spec, Strategy::Conventional, 2, 8, 50.0, exec);
        assert_eq!(seq, par, "diverged with exec={}", exec.name());
        assert!(!seq.is_empty(), "expected some spikes");
    }
}

#[test]
fn structure_aware_with_sparse_threads() {
    // dual pathways with more threads than neurons per area slice
    let spec = models::sanity_net(8, 4).unwrap();
    let seq = run_exec(
        &spec,
        Strategy::StructureAware,
        4,
        6,
        50.0,
        ExecMode::Sequential,
    );
    let bar = run_exec(
        &spec,
        Strategy::StructureAware,
        4,
        6,
        50.0,
        ExecMode::Pooled,
    );
    assert_eq!(seq, bar);
}

#[test]
fn repeated_barrier_runs_are_deterministic() {
    // the barrier runtime re-spawns workers every run; identical inputs
    // must give bit-identical spike trains on every repetition
    let spec = models::sanity_net(200, 4).unwrap();
    let first = run_exec(
        &spec,
        Strategy::StructureAware,
        4,
        4,
        100.0,
        ExecMode::Pooled,
    );
    assert!(first.len() > 100);
    for rep in 0..2 {
        let again = run_exec(
            &spec,
            Strategy::StructureAware,
            4,
            4,
            100.0,
            ExecMode::Pooled,
        );
        assert_eq!(first, again, "repetition {rep} diverged");
    }
}
