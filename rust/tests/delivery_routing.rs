//! Edge cases of the parallel receive side and determinism of the
//! persistent barrier worker runtime (`engine::rank`, `engine::receive`).
//!
//! Workers cooperatively sort the incoming per-sender spike runs,
//! scatter them through `tables::SourceShards` into per-(producer,
//! consumer) buckets, and k-way merge their own buckets back into the
//! canonical delivery order — so correctness hinges on: empty runs and
//! empty buckets being no-ops, spikes from sources without local
//! connections being dropped cleanly, sources fanning out to every
//! thread, interleaved multi-sender runs merging into one canonical
//! stream, threads that own few (or zero) neurons staying in lock-step
//! at the phase barriers, repeated runs being bit-deterministic, and
//! the ring buffers conserving mass (everything delivered is consumed).

use nsim::config::{CommMode, ExecMode, RunConfig, Strategy};
use nsim::engine::{simulate, SimResult};
use nsim::models;
use nsim::network::spec::{
    AreaSpec, DelayDist, LifParams, NeuronKind, WeightRule,
};
use nsim::network::ModelSpec;

fn run_exec(
    spec: &ModelSpec,
    strategy: Strategy,
    m: usize,
    t: usize,
    t_model_ms: f64,
    exec: ExecMode,
) -> Vec<(u64, u32)> {
    let cfg = RunConfig {
        strategy,
        m_ranks: m,
        threads_per_rank: t,
        t_model_ms,
        seed: 12,
        exec,
        record_spikes: true,
        ..RunConfig::default()
    };
    simulate(spec, &cfg).expect("simulation failed").spikes
}

#[test]
fn empty_batches_are_noops() {
    // ignore-and-fire at 2.5 Hz leaves most cycles without any spikes:
    // the deliver phase must route empty batches through the barrier
    // protocol without stalling or corrupting state
    let spec = models::mam_benchmark(4, 0.004, 1.0).unwrap();
    for strategy in [Strategy::Conventional, Strategy::StructureAware] {
        let seq = run_exec(&spec, strategy, 4, 3, 20.0, ExecMode::Sequential);
        let bar = run_exec(&spec, strategy, 4, 3, 20.0, ExecMode::Pooled);
        assert_eq!(seq, bar, "{}: empty-batch cycles diverged", strategy.name());
    }
}

#[test]
fn first_cycle_with_no_received_spikes() {
    // the very first deliver of every run sees empty receive buffers; a
    // single-cycle run exercises exactly that path
    let spec = models::sanity_net(120, 2).unwrap();
    let one_cycle_ms = 2.0; // a handful of cycles at most
    let seq = run_exec(
        &spec,
        Strategy::Conventional,
        2,
        4,
        one_cycle_ms,
        ExecMode::Sequential,
    );
    let bar = run_exec(
        &spec,
        Strategy::Conventional,
        2,
        4,
        one_cycle_ms,
        ExecMode::Pooled,
    );
    assert_eq!(seq, bar);
}

#[test]
fn sources_without_local_targets_are_dropped_cleanly() {
    // round-robin placement scatters connectivity so each rank receives
    // spikes whose sources connect to only a subset of its threads; the
    // sharded router must drop the rest without observable effect
    let spec = models::sanity_net(150, 3).unwrap();
    let seq =
        run_exec(&spec, Strategy::Conventional, 3, 3, 100.0, ExecMode::Sequential);
    assert!(seq.len() > 100, "too quiet to be meaningful");
    let bar =
        run_exec(&spec, Strategy::Conventional, 3, 3, 100.0, ExecMode::Pooled);
    assert_eq!(seq, bar);
}

#[test]
fn more_threads_than_spiking_neurons() {
    // 12 neurons over 2 ranks x 8 threads: most threads host one neuron,
    // some host none — every thread must still participate in all phase
    // barriers every cycle
    let spec = models::sanity_net(6, 2).unwrap();
    for exec in [ExecMode::Pooled, ExecMode::PooledChannels] {
        let seq = run_exec(
            &spec,
            Strategy::Conventional,
            2,
            8,
            50.0,
            ExecMode::Sequential,
        );
        let par = run_exec(&spec, Strategy::Conventional, 2, 8, 50.0, exec);
        assert_eq!(seq, par, "diverged with exec={}", exec.name());
        assert!(!seq.is_empty(), "expected some spikes");
    }
}

#[test]
fn structure_aware_with_sparse_threads() {
    // dual pathways with more threads than neurons per area slice
    let spec = models::sanity_net(8, 4).unwrap();
    let seq = run_exec(
        &spec,
        Strategy::StructureAware,
        4,
        6,
        50.0,
        ExecMode::Sequential,
    );
    let bar = run_exec(
        &spec,
        Strategy::StructureAware,
        4,
        6,
        50.0,
        ExecMode::Pooled,
    );
    assert_eq!(seq, bar);
}

/// Full result (spikes + ring_pending) for the conservation tests.
#[allow(clippy::too_many_arguments)]
fn run_full(
    spec: &ModelSpec,
    strategy: Strategy,
    m: usize,
    t: usize,
    t_model_ms: f64,
    exec: ExecMode,
    comm: CommMode,
) -> SimResult {
    let cfg = RunConfig {
        strategy,
        m_ranks: m,
        threads_per_rank: t,
        t_model_ms,
        seed: 12,
        exec,
        comm,
        record_spikes: true,
        ..RunConfig::default()
    };
    simulate(spec, &cfg).expect("simulation failed")
}

/// LIF net with zero-variance delays pinned to exactly one cycle
/// (intra, 0.1 ms) and one epoch (inter, 1.0 ms), so every spike that is
/// ever delivered into a ring buffer arrives at a step the run also
/// consumes: residual ring mass must be *exactly* 0.0 on every thread —
/// any leak (a write past the horizon, a slot cleared late, a duplicate
/// delivery) shows up as a nonzero residue.
fn conservation_net(n_per_area: u32) -> ModelSpec {
    let params = LifParams {
        i_e_pa: LifParams::default().i_e_for_rate(30.0),
        ..LifParams::default()
    };
    let areas = (0..2u32)
        .map(|i| AreaSpec {
            name: format!("C{i}"),
            n: n_per_area,
            neuron: NeuronKind::Lif(params),
        })
        .collect();
    let k_intra = (n_per_area / 10).clamp(1, n_per_area - 1);
    let k_inter = (n_per_area / 20).max(1);
    ModelSpec::new(
        format!("conserve-{n_per_area}"),
        areas,
        k_intra,
        k_inter,
        WeightRule { w_mv: 0.25, g: 4.0, inh_fraction: 0.2 },
        DelayDist::new(0.1, 0.0, 0.1),
        DelayDist::new(1.0, 0.0, 1.0),
        0.1,
    )
    .unwrap()
}

#[test]
fn ring_buffers_conserve_mass_with_pinned_delays() {
    // every delivered spike is consumed before the run ends (delays are
    // pinned inside the simulated horizon), so pending ring mass is
    // exactly 0.0 — for every strategy, exec mode and comm mode
    let spec = conservation_net(120);
    for strategy in [Strategy::Conventional, Strategy::StructureAware] {
        for exec in [
            ExecMode::Sequential,
            ExecMode::Pooled,
            ExecMode::PooledChannels,
        ] {
            for comm in [CommMode::Blocking, CommMode::Overlap] {
                let res =
                    run_full(&spec, strategy, 2, 3, 50.0, exec, comm);
                assert!(
                    res.spikes.len() > 100,
                    "too quiet to be meaningful: {} spikes",
                    res.spikes.len()
                );
                for (rank, threads) in res.ring_pending.iter().enumerate()
                {
                    assert_eq!(threads.len(), 3);
                    for (th, &pending) in threads.iter().enumerate() {
                        assert_eq!(
                            pending, 0.0,
                            "ring leak on rank {rank} thread {th}: \
                             {pending} ({} exec={} comm={})",
                            strategy.name(),
                            exec.name(),
                            comm.name(),
                        );
                    }
                }
            }
        }
    }
}

#[test]
fn residual_ring_mass_bit_identical_across_modes() {
    // on a net with delay variance the tail mass is nonzero — but it
    // must be bit-identical across exec and comm modes, like the spike
    // trains (the f64 order-independence invariant, asserted end to end)
    let spec = models::sanity_net(200, 4).unwrap();
    let bits = |res: &SimResult| -> Vec<Vec<u64>> {
        res.ring_pending
            .iter()
            .map(|v| v.iter().map(|x| x.to_bits()).collect())
            .collect()
    };
    let base = run_full(
        &spec,
        Strategy::StructureAware,
        4,
        3,
        100.0,
        ExecMode::Sequential,
        CommMode::Blocking,
    );
    let nonzero = base
        .ring_pending
        .iter()
        .flatten()
        .filter(|&&p| p != 0.0)
        .count();
    assert!(nonzero > 0, "variance net left no tail mass — vacuous test");
    for exec in [
        ExecMode::Sequential,
        ExecMode::Pooled,
        ExecMode::PooledChannels,
    ] {
        for comm in [CommMode::Blocking, CommMode::Overlap] {
            let got = run_full(
                &spec,
                Strategy::StructureAware,
                4,
                3,
                100.0,
                exec,
                comm,
            );
            assert_eq!(base.spikes, got.spikes);
            assert_eq!(
                bits(&base),
                bits(&got),
                "residual ring mass diverged: exec={} comm={}",
                exec.name(),
                comm.name()
            );
        }
    }
}

#[test]
fn source_fanning_out_to_every_thread() {
    // all-to-all connectivity in one area: every spike's connection
    // group exists on every thread, so each bucketed spike lands in all
    // T grid buckets and every worker merges every source
    let params = LifParams {
        i_e_pa: LifParams::default().i_e_for_rate(30.0),
        ..LifParams::default()
    };
    let n = 24u32;
    let spec = ModelSpec::new(
        "fanout".into(),
        vec![AreaSpec {
            name: "F".into(),
            n,
            neuron: NeuronKind::Lif(params),
        }],
        n - 1, // full intra-area fan-in
        0,
        WeightRule { w_mv: 0.25, g: 4.0, inh_fraction: 0.2 },
        DelayDist::new(1.25, 0.625, 0.1),
        DelayDist::new(5.0, 2.5, 1.0),
        0.1,
    )
    .unwrap();
    let seq =
        run_exec(&spec, Strategy::Conventional, 1, 8, 100.0, ExecMode::Sequential);
    assert!(seq.len() > 100, "too quiet to be meaningful");
    for exec in [ExecMode::Pooled, ExecMode::PooledChannels] {
        let par = run_exec(&spec, Strategy::Conventional, 1, 8, 100.0, exec);
        assert_eq!(seq, par, "diverged with exec={}", exec.name());
    }
}

#[test]
fn interleaved_multi_sender_runs_merge_canonically() {
    // grouped hierarchy: the local tier delivers one run per group
    // member and the global tier one run per rank, so every deliver
    // phase k-way merges interleaved multi-sender runs; the merged
    // stream must reproduce the sequential reference exactly
    let spec = models::sanity_net(160, 4).unwrap();
    let run_hier = |exec: ExecMode| {
        let cfg = RunConfig {
            strategy: Strategy::StructureAware,
            m_ranks: 8,
            threads_per_rank: 4,
            t_model_ms: 100.0,
            seed: 12,
            exec,
            ranks_per_area: 2,
            record_spikes: true,
            ..RunConfig::default()
        };
        simulate(&spec, &cfg).expect("simulation failed").spikes
    };
    let seq = run_hier(ExecMode::Sequential);
    assert!(seq.len() > 100, "too quiet to be meaningful");
    for exec in [ExecMode::Pooled, ExecMode::PooledChannels] {
        assert_eq!(seq, run_hier(exec), "diverged with exec={}", exec.name());
    }
}

#[test]
fn repeated_barrier_runs_are_deterministic() {
    // the barrier runtime re-spawns workers every run; identical inputs
    // must give bit-identical spike trains on every repetition
    let spec = models::sanity_net(200, 4).unwrap();
    let first = run_exec(
        &spec,
        Strategy::StructureAware,
        4,
        4,
        100.0,
        ExecMode::Pooled,
    );
    assert!(first.len() > 100);
    for rep in 0..2 {
        let again = run_exec(
            &spec,
            Strategy::StructureAware,
            4,
            4,
            100.0,
            ExecMode::Pooled,
        );
        assert_eq!(first, again, "repetition {rep} diverged");
    }
}
