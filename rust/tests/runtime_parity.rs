//! Three-layer composition proof: the AOT-compiled XLA artifacts (L1
//! Pallas kernel inside the L2 jax step function, loaded via PJRT) must
//! reproduce the native Rust update exactly — and a whole simulation run
//! through the XLA path must emit the same spikes as the native path.
//!
//! Requires `make artifacts` (skipped gracefully if absent) and a build
//! with the `xla` feature (the whole suite is compiled out without it —
//! see `Cargo.toml`).

#![cfg(feature = "xla")]

use nsim::config::{RunConfig, Strategy, UpdatePath};
use nsim::engine::neuron::NeuronBlock;
use nsim::engine::simulate;
use nsim::models;
use nsim::network::spec::{LifParams, NeuronKind};
use nsim::runtime::updater::xla_updater;
use nsim::util::rng::Pcg64;

fn artifacts_available() -> bool {
    let dir = nsim::runtime::registry::default_dir();
    std::path::Path::new(&format!("{dir}/manifest.json")).exists()
}

#[test]
fn xla_lif_step_matches_native_bitwise() {
    if !artifacts_available() {
        eprintln!("skipping: no artifacts (run `make artifacts`)");
        return;
    }
    let spec = models::sanity_net(100, 2).unwrap();
    let updater = xla_updater(&spec).expect("xla updater");

    let gids: Vec<u32> = (0..700).collect(); // not a multiple of 512
    let params = LifParams {
        i_e_pa: LifParams::default().i_e_for_rate(12.0),
        ..Default::default()
    };
    let mut native =
        NeuronBlock::build(&gids, 0.1, |_| NeuronKind::Lif(params));
    let mut xla = native.clone();
    let mut rng = Pcg64::seed_from_u64(5);

    for step in 0..50 {
        let syn: Vec<f32> = (0..gids.len())
            .map(|_| rng.normal_ms(0.1, 0.5) as f32)
            .collect();
        let mut native_spikes = Vec::new();
        let mut xla_spikes = Vec::new();
        native.step_native(&syn, &mut native_spikes);
        updater.step(&mut xla, &syn, &mut xla_spikes);
        assert_eq!(
            native_spikes, xla_spikes,
            "spike mismatch at step {step}"
        );
        match (&native, &xla) {
            (
                NeuronBlock::Lif { v: v_n, refr: r_n, .. },
                NeuronBlock::Lif { v: v_x, refr: r_x, .. },
            ) => {
                assert_eq!(v_n, v_x, "membrane mismatch at step {step}");
                assert_eq!(r_n, r_x, "refractory mismatch at step {step}");
            }
            _ => unreachable!(),
        }
    }
}

#[test]
fn xla_ianf_step_matches_native() {
    if !artifacts_available() {
        eprintln!("skipping: no artifacts (run `make artifacts`)");
        return;
    }
    let spec = models::mam_benchmark(2, 0.001, 1.0).unwrap();
    let updater = xla_updater(&spec).expect("xla updater");
    let gids: Vec<u32> = (0..300).collect();
    let mut native = NeuronBlock::build(&gids, 0.1, |_| {
        NeuronKind::IgnoreAndFire { interval_steps: 37 }
    });
    let mut xla = native.clone();
    let syn = vec![0.0f32; 300];
    for step in 0..80 {
        let mut sn = Vec::new();
        let mut sx = Vec::new();
        native.step_native(&syn, &mut sn);
        updater.step(&mut xla, &syn, &mut sx);
        assert_eq!(sn, sx, "ianf spike mismatch at step {step}");
    }
}

#[test]
fn full_simulation_identical_through_xla_path() {
    if !artifacts_available() {
        eprintln!("skipping: no artifacts (run `make artifacts`)");
        return;
    }
    let spec = models::sanity_net(150, 2).unwrap();
    let run = |update_path| {
        let cfg = RunConfig {
            strategy: Strategy::StructureAware,
            m_ranks: 2,
            threads_per_rank: 2,
            t_model_ms: 50.0,
            seed: 12,
            update_path,
            record_spikes: true,
            ..RunConfig::default()
        };
        simulate(&spec, &cfg).unwrap().spikes
    };
    let native = run(UpdatePath::Native);
    let xla = run(UpdatePath::Xla);
    assert!(!native.is_empty());
    assert_eq!(native, xla, "XLA path diverged from native path");
}
