//! Micro-benchmarks of the L3 hot paths (EXPERIMENTS.md §Perf).
//!
//! No criterion in the offline registry, so this uses a small in-tree
//! harness: warmup, then timed batches until the window elapses,
//! reporting ns/op and throughput.
//!
//!     cargo bench --bench hotpath
//!     cargo bench --bench hotpath -- --smoke --bench-json BENCH_hotpath.json
//!
//! `--bench-json <path>` writes every measurement — micro ns/op plus the
//! engine end-to-end comparisons with per-phase timings and RTF — as a
//! JSON document so the perf trajectory is tracked across PRs (the CI
//! bench-regression job diffs it against the base branch via
//! `tools/bench_compare.py`); `--smoke` shrinks windows and model times
//! for CI.  The engine section includes a split-phase depth sweep
//! (`comm_depth` 1/2/4 on the deep-pipeline net), a flat-vs-hierarchical
//! structure-aware pair (`ranks_per_area` 1 and 2 on the deliver-heavy
//! net, with per-tier local/global traffic and wait in the JSON) and the
//! blocking-vs-overlap A/B.

use nsim::comm::{SpikeMsg, Transport, WorldBuilder};
use nsim::config::{CommMode, ExecMode, RunConfig, Strategy};
use nsim::engine::neuron::NeuronBlock;
use nsim::engine::receive::{bucket_runs, merge_routed, RoutedSpike};
use nsim::engine::ringbuffer::RingBuffer;
use nsim::engine::simulate;
use nsim::models;
use nsim::network::spec::{
    AreaSpec, DelayDist, LifParams, NeuronKind, WeightRule,
};
use nsim::network::ModelSpec;
use nsim::tables::{ConnTable, LocalConn, SourceShards, TargetTable};
use nsim::util::json::Json;
use nsim::util::rng::Pcg64;
use nsim::util::timers::Phase;
use nsim::vcluster::{run_cluster, MachineProfile, VcOptions, Workload};
use std::hint::black_box;
use std::time::Instant;

struct Harness {
    /// Timed-batch window per micro bench, seconds.
    window: f64,
    /// (name, ns/op, Mops/s) of every micro bench run.
    micro: Vec<(String, f64, f64)>,
    /// One JSON object per engine end-to-end run.
    engine: Vec<Json>,
    /// Record obs spans in subsequent engine runs (the tracing-overhead
    /// A/B flips this on for its traced arm only).
    trace: bool,
}

impl Harness {
    /// Time `f` (which performs `ops_per_call` operations) and report.
    fn bench(&mut self, name: &str, ops_per_call: u64, mut f: impl FnMut()) {
        // warmup
        for _ in 0..3 {
            f();
        }
        let mut calls = 0u64;
        let t0 = Instant::now();
        while t0.elapsed().as_secs_f64() < self.window {
            f();
            calls += 1;
        }
        let secs = t0.elapsed().as_secs_f64();
        let ops = calls * ops_per_call;
        let ns_per_op = secs * 1e9 / ops as f64;
        let mops = ops as f64 / secs / 1e6;
        println!("{name:<42} {ns_per_op:>9.2} ns/op  {mops:>10.2} Mops/s");
        self.micro.push((name.to_string(), ns_per_op, mops));
    }

    /// Run the functional engine once and record wall time, throughput,
    /// per-phase means and RTF.
    #[allow(clippy::too_many_arguments)]
    fn engine_run(
        &mut self,
        model: &str,
        spec: &ModelSpec,
        strategy: Strategy,
        exec: ExecMode,
        comm: CommMode,
        comm_depth: usize,
        ranks_per_area: usize,
        m: usize,
        threads: usize,
        t_model_ms: f64,
    ) -> f64 {
        let cfg = RunConfig {
            strategy,
            m_ranks: m,
            threads_per_rank: threads,
            t_model_ms,
            seed: 654,
            exec,
            comm,
            comm_depth,
            ranks_per_area,
            trace: self.trace,
            ..RunConfig::default()
        };
        let t0 = Instant::now();
        let res = simulate(spec, &cfg).unwrap();
        let secs = t0.elapsed().as_secs_f64();
        let neuron_steps = spec.total_neurons() as f64 * res.s_cycles as f64;
        let mcps = neuron_steps / secs / 1e6;
        println!(
            "engine: {model:<14} {:<16} {:<16} {:<8} d={comm_depth} \
             R={ranks_per_area} T={threads} {} neurons x {} cycles in \
             {secs:.3} s = {mcps:.2} M neuron-cycles/s (sync {:.4} s, \
             hidden {:.4} s)",
            strategy.name(),
            exec.name(),
            comm.name(),
            spec.total_neurons(),
            res.s_cycles,
            res.mean_times.get(Phase::Synchronize),
            res.comm_stats.hidden_secs / m as f64,
        );
        let tiers = &res.comm_tiers;
        // which receive side the exec mode runs: the legacy channel pool
        // is the coordinator-sorted broadcast (the "old" delivery arm),
        // everything else the parallel bucket/merge path — the
        // deliver-heavy configs pair the two as the engine-level A/B
        let delivery = match exec {
            ExecMode::PooledChannels => "broadcast",
            _ => "merge",
        };
        self.engine.push(Json::obj(vec![
            ("model", model.into()),
            ("strategy", strategy.name().into()),
            ("exec", exec.name().into()),
            ("delivery", delivery.into()),
            ("comm", comm.name().into()),
            // the bench harness always runs the in-process backend;
            // the axis exists so socket runs recorded by other tools
            // never silently compare against shmem baselines
            ("transport", "shmem".into()),
            ("comm_depth", comm_depth.into()),
            ("ranks_per_area", ranks_per_area.into()),
            ("ranks", m.into()),
            ("threads", threads.into()),
            ("t_model_ms", t_model_ms.into()),
            ("wall_s", secs.into()),
            ("neuron_cycles_per_s", (neuron_steps / secs).into()),
            ("rtf", res.rtf().into()),
            ("deliver_s", res.mean_times.get(Phase::Deliver).into()),
            ("update_s", res.mean_times.get(Phase::Update).into()),
            ("collocate_s", res.mean_times.get(Phase::Collocate).into()),
            (
                "synchronize_s",
                res.mean_times.get(Phase::Synchronize).into(),
            ),
            (
                "exchange_s",
                res.mean_times.get(Phase::DataExchange).into(),
            ),
            // total split-phase completions across all m ranks
            (
                "overlapped_exchanges",
                (res.comm_stats.overlapped_exchanges as f64).into(),
            ),
            // per-rank means, same scale as the phase timings above (the
            // CommStats duration counters aggregate over all m ranks)
            ("post_s", (res.comm_stats.post_secs / m as f64).into()),
            (
                "complete_wait_s",
                (res.comm_stats.complete_wait_secs / m as f64).into(),
            ),
            (
                "hidden_s",
                (res.comm_stats.hidden_secs / m as f64).into(),
            ),
            // per-tier traffic and wait of the hierarchical schedule
            // (local tier all zero unless the run splits communicators)
            (
                "local_exchanges",
                (tiers.local.alltoall_calls as f64).into(),
            ),
            ("local_swaps", (tiers.local.local_swaps as f64).into()),
            ("local_bytes", (tiers.local.bytes_sent as f64).into()),
            (
                "local_wait_s",
                ((tiers.local.sync_secs + tiers.local.complete_wait_secs)
                    / m as f64)
                    .into(),
            ),
            (
                "global_exchanges",
                (tiers.global.alltoall_calls as f64).into(),
            ),
            ("global_bytes", (tiers.global.bytes_sent as f64).into()),
            (
                "global_wait_s",
                ((tiers.global.sync_secs
                    + tiers.global.complete_wait_secs)
                    / m as f64)
                    .into(),
            ),
        ]));
        secs
    }
}

/// Deliver-heavy LIF net for the overlap A/B: four areas with the last
/// one 3x larger, so under area-aligned placement its rank is the
/// persistent straggler every blocking barrier waits for.  Inter-area
/// delays are drawn tightly around 5 ms, keeping every rank's realized
/// minimum incoming long-range delay far above the 1 ms `d_min_inter`
/// cutoff (D = 10) — multi-cycle deadline slack for the split-phase
/// exchange to hide the straggler's skew in.
fn overlap_net(n_base: u32) -> anyhow::Result<ModelSpec> {
    let params = LifParams {
        i_e_pa: LifParams::default().i_e_for_rate(30.0),
        ..LifParams::default()
    };
    let areas = (0..4u32)
        .map(|i| AreaSpec {
            name: format!("O{i}"),
            n: if i == 3 { 3 * n_base } else { n_base },
            neuron: NeuronKind::Lif(params),
        })
        .collect();
    let k_intra = (n_base / 10).clamp(1, n_base - 1);
    let k_inter = (n_base / 20).max(1);
    ModelSpec::new(
        format!("overlap-{n_base}"),
        areas,
        k_intra,
        k_inter,
        WeightRule { w_mv: 0.25, g: 4.0, inh_fraction: 0.2 },
        DelayDist::new(1.25, 0.625, 0.1),
        DelayDist::new(5.0, 0.4, 1.0),
        0.1,
    )
}

fn main() {
    let mut smoke = false;
    let mut json_path: Option<String> = None;
    let mut args = std::env::args().skip(1);
    while let Some(a) = args.next() {
        match a.as_str() {
            "--smoke" => smoke = true,
            "--bench-json" => {
                json_path = Some(
                    args.next().expect("--bench-json needs a path argument"),
                );
            }
            // cargo bench passes --bench through to the binary
            "--bench" => {}
            other => eprintln!("ignoring unknown bench option {other:?}"),
        }
    }
    let mut h = Harness {
        window: if smoke { 0.05 } else { 0.25 },
        micro: Vec::new(),
        engine: Vec::new(),
        trace: false,
    };

    println!("== L3 hot-path micro-benchmarks ==\n");

    // --- RNG ---------------------------------------------------------
    let mut rng = Pcg64::seed_from_u64(1);
    h.bench("rng: next_u64", 1024, || {
        for _ in 0..1024 {
            black_box(rng.next_u64());
        }
    });
    let mut rng = Pcg64::seed_from_u64(1);
    h.bench("rng: normal", 1024, || {
        for _ in 0..1024 {
            black_box(rng.normal());
        }
    });

    // --- connection-table lookup (spike delivery core) ---------------
    let mut rng = Pcg64::seed_from_u64(2);
    let n_sources = 10_000u32;
    let entries: Vec<(u32, LocalConn)> = (0..600_000)
        .map(|i| {
            (
                rng.below(n_sources as u64) as u32,
                LocalConn {
                    target_local: i as u32 % 4096,
                    weight: 0.125,
                    delay_steps: 1 + (i % 50) as u16,
                },
            )
        })
        .collect();
    let table = ConnTable::build(entries);
    let probes: Vec<u32> =
        (0..1024).map(|_| rng.below(n_sources as u64) as u32).collect();
    h.bench("tables: ConnTable::lookup", probes.len() as u64, || {
        for &p in &probes {
            black_box(table.lookup(p).len());
        }
    });

    // --- ring buffer -------------------------------------------------
    let mut ring = RingBuffer::new(4096, 64);
    h.bench("ring: add", 4096, || {
        for i in 0..4096u32 {
            ring.add((i % 60) as u64, i % 4096, 0.125);
        }
    });
    let mut row = vec![0.0f32; 4096];
    h.bench("ring: take_row (4096 lanes)", 4096, || {
        ring.take_row(black_box(7), &mut row);
        black_box(&row);
    });

    // --- delivery: lookup + ring add combined ------------------------
    h.bench("deliver: spike -> conns -> ring", probes.len() as u64, || {
        for &p in &probes {
            for c in table.lookup(p).iter() {
                ring.add(10 + c.delay_steps as u64, c.target_local, c.weight);
            }
        }
    });

    // --- delivery A/B: old broadcast walk vs new bucket/merge path -----
    // unique (source, cycle) keys, as spike compression guarantees on
    // the real receive path (i*97 is injective mod the source count)
    let batch: Vec<SpikeMsg> = (0..1024)
        .map(|i| SpikeMsg {
            source: (i * 97 % n_sources as usize) as u32,
            cycle: (i % 10) as u32,
        })
        .collect();
    // old arm: flatten, one canonical sort over the whole batch, then a
    // per-spike binary-search lookup and per-connection ring adds — what
    // `pooled_deliver` broadcast to every worker
    let mut scratch = batch.clone();
    h.bench("deliver: batch sort + route (old)", batch.len() as u64, || {
        scratch.clear();
        scratch.extend_from_slice(&batch);
        scratch.sort_unstable_by_key(|m| (m.source, m.cycle));
        for msg in &scratch {
            for c in table.lookup(msg.source).iter() {
                ring.add(
                    msg.cycle as u64 + c.delay_steps as u64,
                    c.target_local,
                    c.weight,
                );
            }
        }
    });
    // new arm: the parallel receive path on the same batch — per-run
    // sorts, shard-routed bucketing (group index resolved once), k-way
    // merge, then whole delay buckets accumulated per slot row
    let shards = SourceShards::build([&table]);
    let n_runs = 4usize;
    let run_src: Vec<Vec<SpikeMsg>> = (0..n_runs)
        .map(|r| batch.iter().skip(r).step_by(n_runs).copied().collect())
        .collect();
    let mut runs: Vec<Vec<SpikeMsg>> = vec![Vec::new(); n_runs];
    let mut heads: Vec<usize> = Vec::new();
    let mut bucket: Vec<RoutedSpike> = Vec::new();
    h.bench(
        "deliver: bucket + merge + rows (new)",
        batch.len() as u64,
        || {
            for (dst, src) in runs.iter_mut().zip(&run_src) {
                dst.clear();
                dst.extend_from_slice(src);
            }
            bucket.clear();
            bucket_runs(&shards, &mut runs, &mut heads, |_, sp| {
                bucket.push(sp)
            });
            let views = [bucket.as_slice()];
            merge_routed(&views, &mut heads, |sp| {
                for (delay, targets, weights) in
                    table.group(sp.group as usize).delay_runs()
                {
                    ring.accumulate_row(
                        sp.cycle as u64 + delay as u64,
                        targets,
                        weights,
                    );
                }
            });
        },
    );

    // --- collocate: registers -> per-rank send buffers ----------------
    let m_dest = 8usize;
    let mut targets = TargetTable::new(4096);
    let mut rng = Pcg64::seed_from_u64(4);
    for i in 0..4096 {
        for _ in 0..3 {
            targets.add(i, rng.below(m_dest as u64) as u16);
        }
    }
    let register: Vec<(u32, u64)> =
        (0..1024u64).map(|i| (((i * 4) % 4096) as u32, i)).collect();
    let gids: Vec<u32> = (0..4096).collect();
    let mut send_bufs: Vec<Vec<SpikeMsg>> =
        (0..m_dest).map(|_| Vec::new()).collect();
    h.bench(
        "collocate: register -> send buffers",
        register.len() as u64,
        || {
            for &(idx, step) in &register {
                let gid = gids[idx as usize];
                for &r in targets.ranks(idx as usize) {
                    send_bufs[r as usize].push(SpikeMsg {
                        source: gid,
                        cycle: step as u32,
                    });
                }
            }
            for b in &mut send_bufs {
                b.clear();
            }
        },
    );

    // --- exchange: recycled vs allocating transport -------------------
    let world = WorldBuilder::new(1).build();
    let comm = world.communicator(0);
    let payload: Vec<SpikeMsg> = (0..512)
        .map(|i| SpikeMsg { source: i, cycle: 0 })
        .collect();
    let mut a2a_send = vec![Vec::with_capacity(512)];
    let mut a2a_recv: Vec<Vec<SpikeMsg>> = Vec::new();
    h.bench("exchange: alltoall_into (recycled)", 512, || {
        a2a_send[0].extend_from_slice(&payload);
        comm.alltoall_into(&mut a2a_send, &mut a2a_recv)
            .expect("alltoall_into failed");
        black_box(a2a_recv[0].len());
    });
    h.bench("exchange: alltoall (fresh alloc)", 512, || {
        a2a_send[0].extend_from_slice(&payload);
        let (recv, _) =
            comm.alltoall(&mut a2a_send).expect("alltoall failed");
        black_box(recv[0].len());
    });
    let mut swap_send = Vec::with_capacity(512);
    let mut swap_recv = Vec::new();
    h.bench("exchange: local_swap_into", 512, || {
        swap_send.extend_from_slice(&payload);
        comm.local_swap_into(&mut swap_send, &mut swap_recv);
        black_box(swap_recv.len());
    });

    // --- neuron update ------------------------------------------------
    let gids: Vec<u32> = (0..8192).collect();
    let params = LifParams {
        i_e_pa: LifParams::default().i_e_for_rate(8.0),
        ..Default::default()
    };
    let mut block =
        NeuronBlock::build(&gids, 0.1, |_| NeuronKind::Lif(params));
    let syn = vec![0.01f32; 8192];
    let mut spikes = Vec::new();
    h.bench("update: LIF step (8192 lanes)", 8192, || {
        spikes.clear();
        block.step_native(&syn, &mut spikes);
        black_box(&spikes);
    });
    let mut ianf = NeuronBlock::build(&gids, 0.1, |_| {
        NeuronKind::IgnoreAndFire { interval_steps: 4000 }
    });
    h.bench("update: ignore-and-fire step (8192)", 8192, || {
        spikes.clear();
        ianf.step_native(&syn, &mut spikes);
        black_box(&spikes);
    });

    // --- virtual cluster throughput -----------------------------------
    println!("\n== macro benchmarks ==\n");
    let vc_ranks = if smoke { 16 } else { 128 };
    let vc_t_model = if smoke { 100.0 } else { 1_000.0 };
    let machine = MachineProfile::supermuc_ng();
    let spec = models::mam_benchmark(vc_ranks, 1.0, 1.0).unwrap();
    let w =
        Workload::derive(&spec, Strategy::Conventional, vc_ranks, 48).unwrap();
    let t0 = Instant::now();
    let opts = VcOptions {
        t_model_ms: vc_t_model,
        h_ms: 0.1,
        seed: 654,
        record_cycle_times: false,
    };
    let res = run_cluster(&machine, &w, &opts).unwrap();
    let vc_secs = t0.elapsed().as_secs_f64();
    let rank_cycles = vc_ranks as f64 * res.s_cycles as f64;
    println!(
        "vcluster: M={vc_ranks} x {} cycles in {vc_secs:.3} s = \
         {:.2} M rank-cycles/s",
        res.s_cycles,
        rank_cycles / vc_secs / 1e6
    );
    let vcluster_json = Json::obj(vec![
        ("ranks", vc_ranks.into()),
        ("cycles", (res.s_cycles as f64).into()),
        ("wall_s", vc_secs.into()),
        ("rank_cycles_per_s", (rank_cycles / vc_secs).into()),
    ]);

    // --- functional engine end-to-end: sequential vs pooled -----------
    println!();
    let t_model = if smoke { 20.0 } else { 100.0 };
    let spec = models::mam_benchmark(4, 0.01, 1.0).unwrap();
    for strategy in [Strategy::Conventional, Strategy::StructureAware] {
        for (exec, threads) in [
            (ExecMode::Sequential, 1),
            (ExecMode::Pooled, 1), // must match sequential: no pool at T=1
            (ExecMode::Sequential, 4),
            (ExecMode::Pooled, 4),
            (ExecMode::PooledChannels, 4),
        ] {
            h.engine_run(
                "mamb-4",
                &spec,
                strategy,
                exec,
                CommMode::Blocking,
                1,
                1,
                4,
                threads,
                t_model,
            );
        }
    }

    // --- deliver-heavy A/B: barrier runtime vs legacy channel pool ----
    // dense LIF net (~300 connections/neuron, every neuron near 30 Hz):
    // the deliver phase dominates, which is where thread-sharded routing
    // and the barrier protocol pay off
    println!();
    let heavy_n = if smoke { 500 } else { 2000 };
    let heavy_t_model = if smoke { 20.0 } else { 100.0 };
    let heavy = models::sanity_net(heavy_n, 4).unwrap();
    let mut heavy_pooled_wall = 0.0;
    for (exec, threads) in [
        (ExecMode::Sequential, 4),
        (ExecMode::PooledChannels, 4),
        (ExecMode::Pooled, 4),
    ] {
        let wall = h.engine_run(
            "deliver-heavy",
            &heavy,
            Strategy::Conventional,
            exec,
            CommMode::Blocking,
            1,
            1,
            2,
            threads,
            heavy_t_model,
        );
        if matches!(exec, ExecMode::Pooled) {
            heavy_pooled_wall = wall;
        }
    }

    // --- hierarchical two-tier: areas spanning rank groups ------------
    // the same deliver-heavy net under the structure-aware strategy:
    // flat (one area per rank, M=4) vs hierarchical (each area spanning
    // a two-rank group, M=8, ranks_per_area=2).  The hierarchical config
    // runs a real intra-group alltoall on the local tier every cycle;
    // its local/global tier stats land in the bench JSON next to the
    // RTF, keyed by ranks_per_area.
    println!();
    for (m, rpa) in [(4usize, 1usize), (8, 2)] {
        h.engine_run(
            "deliver-heavy",
            &heavy,
            Strategy::StructureAware,
            ExecMode::Pooled,
            CommMode::Blocking,
            1,
            rpa,
            m,
            2,
            heavy_t_model,
        );
    }

    // --- latency-hiding A/B: blocking vs split-phase overlap ----------
    // deliver-heavy LIF net with deliberately imbalanced areas (the last
    // area is 3x the others, so its rank is the persistent straggler
    // every rank waits for at the blocking barrier) and realized
    // inter-area delays well above the d_min_inter cutoff (narrow-sigma
    // distribution), which gives every rank several cycles of deadline
    // slack to hide the straggler's skew in
    println!();
    let ov_n = if smoke { 400 } else { 1200 };
    let ov_t_model = if smoke { 20.0 } else { 100.0 };
    let ov_spec = overlap_net(ov_n).unwrap();
    for comm in [CommMode::Blocking, CommMode::Overlap] {
        h.engine_run(
            "deliver-heavy-ov",
            &ov_spec,
            Strategy::StructureAware,
            ExecMode::Pooled,
            comm,
            1,
            1,
            4,
            2,
            ov_t_model,
        );
    }

    // --- depth sweep: conventional pipeline depth 1 / 2 / 4 -----------
    // deep-pipeline net: every realized delay sits near 5 cycles above
    // the 1 ms cutoff, so a conventional run — which normally eats the
    // full barrier skew every min-delay interval — can keep up to four
    // exchange rounds in flight.  The sweep is the A/B for the depth-D
    // split-phase pipeline: blocking baseline, then overlap at depth 1
    // (post/complete within one interval), 2 and 4.
    println!();
    let dp_n = if smoke { 300 } else { 1000 };
    let dp_t_model = if smoke { 20.0 } else { 100.0 };
    let dp_spec = models::deep_pipeline_net(dp_n, 4).unwrap();
    h.engine_run(
        "deep-pipeline",
        &dp_spec,
        Strategy::Conventional,
        ExecMode::Pooled,
        CommMode::Blocking,
        1,
        1,
        4,
        2,
        dp_t_model,
    );
    for depth in [1usize, 2, 4] {
        h.engine_run(
            "deep-pipeline",
            &dp_spec,
            Strategy::Conventional,
            ExecMode::Pooled,
            CommMode::Overlap,
            depth,
            1,
            4,
            2,
            dp_t_model,
        );
    }

    // --- observability overhead A/B: span tracing off vs on -----------
    // same config as the Pooled deliver-heavy arm above, with full span
    // recording enabled.  The traced run is keyed under its own model
    // name so the untraced "deliver-heavy" keys keep gating against the
    // existing baselines, while this key tracks the tracing overhead on
    // its own trajectory.  The wall-clock ratio against the untraced
    // Pooled arm is the overhead guard: spans are ~100 ns of clock reads
    // and a buffered push each, so the ratio should stay near 1.
    println!();
    h.trace = true;
    let traced_wall = h.engine_run(
        "deliver-heavy-traced",
        &heavy,
        Strategy::Conventional,
        ExecMode::Pooled,
        CommMode::Blocking,
        1,
        1,
        2,
        4,
        heavy_t_model,
    );
    h.trace = false;
    println!(
        "obs overhead: traced/untraced wall ratio {:.3} (traced \
         {traced_wall:.3} s vs {heavy_pooled_wall:.3} s)",
        traced_wall / heavy_pooled_wall.max(1e-12),
    );

    if let Some(path) = json_path {
        let micro = Json::Arr(
            h.micro
                .iter()
                .map(|(name, ns, mops)| {
                    Json::obj(vec![
                        ("name", name.as_str().into()),
                        ("ns_per_op", (*ns).into()),
                        ("mops_per_s", (*mops).into()),
                    ])
                })
                .collect(),
        );
        let doc = Json::obj(vec![
            ("bench", "hotpath".into()),
            ("smoke", smoke.into()),
            ("micro", micro),
            ("vcluster", vcluster_json),
            ("engine", Json::Arr(h.engine.clone())),
        ]);
        std::fs::write(&path, format!("{doc}\n"))
            .unwrap_or_else(|e| panic!("writing {path}: {e}"));
        println!("\nwrote {path}");
    }
}
