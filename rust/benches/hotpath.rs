//! Micro-benchmarks of the L3 hot paths (EXPERIMENTS.md §Perf).
//!
//! No criterion in the offline registry, so this uses a small in-tree
//! harness: warmup, then timed batches until ≥ 0.25 s elapsed, reporting
//! ns/op and throughput.
//!
//!     cargo bench --bench hotpath

use nsim::comm::{SpikeMsg, Transport, World};
use nsim::config::{ExecMode, RunConfig, Strategy};
use nsim::engine::neuron::NeuronBlock;
use nsim::engine::ringbuffer::RingBuffer;
use nsim::engine::simulate;
use nsim::models;
use nsim::network::spec::{LifParams, NeuronKind};
use nsim::tables::{ConnTable, LocalConn, TargetTable};
use nsim::util::rng::Pcg64;
use nsim::vcluster::{run_cluster, MachineProfile, VcOptions, Workload};
use std::hint::black_box;
use std::time::Instant;

/// Time `f` (which performs `ops_per_call` operations) and report.
fn bench(name: &str, ops_per_call: u64, mut f: impl FnMut()) {
    // warmup
    for _ in 0..3 {
        f();
    }
    let mut calls = 0u64;
    let t0 = Instant::now();
    while t0.elapsed().as_secs_f64() < 0.25 {
        f();
        calls += 1;
    }
    let secs = t0.elapsed().as_secs_f64();
    let ops = calls * ops_per_call;
    let ns_per_op = secs * 1e9 / ops as f64;
    println!(
        "{name:<42} {ns_per_op:>9.2} ns/op  {:>10.2} Mops/s",
        ops as f64 / secs / 1e6
    );
}

fn main() {
    println!("== L3 hot-path micro-benchmarks ==\n");

    // --- RNG ---------------------------------------------------------
    let mut rng = Pcg64::seed_from_u64(1);
    bench("rng: next_u64", 1024, || {
        for _ in 0..1024 {
            black_box(rng.next_u64());
        }
    });
    bench("rng: normal", 1024, || {
        for _ in 0..1024 {
            black_box(rng.normal());
        }
    });

    // --- connection-table lookup (spike delivery core) ---------------
    let mut rng = Pcg64::seed_from_u64(2);
    let n_sources = 10_000u32;
    let entries: Vec<(u32, LocalConn)> = (0..600_000)
        .map(|i| {
            (
                rng.below(n_sources as u64) as u32,
                LocalConn {
                    target_local: i as u32 % 4096,
                    weight: 0.125,
                    delay_steps: 1 + (i % 50) as u16,
                },
            )
        })
        .collect();
    let table = ConnTable::build(entries);
    let probes: Vec<u32> =
        (0..1024).map(|_| rng.below(n_sources as u64) as u32).collect();
    bench("tables: ConnTable::lookup", probes.len() as u64, || {
        for &p in &probes {
            black_box(table.lookup(p));
        }
    });

    // --- ring buffer -------------------------------------------------
    let mut ring = RingBuffer::new(4096, 64);
    bench("ring: add", 4096, || {
        for i in 0..4096u32 {
            ring.add((i % 60) as u64, i % 4096, 0.125);
        }
    });
    let mut row = vec![0.0f32; 4096];
    bench("ring: take_row (4096 lanes)", 4096, || {
        ring.take_row(black_box(7), &mut row);
        black_box(&row);
    });

    // --- delivery: lookup + ring add combined ------------------------
    bench("deliver: spike -> conns -> ring", probes.len() as u64, || {
        for &p in &probes {
            for c in table.lookup(p) {
                ring.add(10 + c.delay_steps as u64, c.target_local, c.weight);
            }
        }
    });

    // --- delivery: full batch path (canonical sort + route) -----------
    let batch: Vec<SpikeMsg> = (0..1024)
        .map(|i| SpikeMsg {
            source: rng.below(n_sources as u64) as u32,
            cycle: (i % 10) as u32,
        })
        .collect();
    let mut scratch = batch.clone();
    bench("deliver: batch sort + route", batch.len() as u64, || {
        scratch.clear();
        scratch.extend_from_slice(&batch);
        scratch.sort_unstable_by_key(|m| (m.source, m.cycle));
        for msg in &scratch {
            for c in table.lookup(msg.source) {
                ring.add(
                    msg.cycle as u64 + c.delay_steps as u64,
                    c.target_local,
                    c.weight,
                );
            }
        }
    });

    // --- collocate: registers -> per-rank send buffers ----------------
    let m_dest = 8usize;
    let mut targets = TargetTable::new(4096);
    let mut rng = Pcg64::seed_from_u64(4);
    for i in 0..4096 {
        for _ in 0..3 {
            targets.add(i, rng.below(m_dest as u64) as u16);
        }
    }
    let register: Vec<(u32, u64)> =
        (0..1024u64).map(|i| (((i * 4) % 4096) as u32, i)).collect();
    let gids: Vec<u32> = (0..4096).collect();
    let mut send_bufs: Vec<Vec<SpikeMsg>> =
        (0..m_dest).map(|_| Vec::new()).collect();
    bench(
        "collocate: register -> send buffers",
        register.len() as u64,
        || {
            for &(idx, step) in &register {
                let gid = gids[idx as usize];
                for &r in targets.ranks(idx as usize) {
                    send_bufs[r as usize].push(SpikeMsg {
                        source: gid,
                        cycle: step as u32,
                    });
                }
            }
            for b in &mut send_bufs {
                b.clear();
            }
        },
    );

    // --- exchange: recycled vs allocating transport -------------------
    let world = World::new(1, 1024);
    let comm = world.communicator(0);
    let payload: Vec<SpikeMsg> = (0..512)
        .map(|i| SpikeMsg { source: i, cycle: 0 })
        .collect();
    let mut a2a_send = vec![Vec::with_capacity(512)];
    let mut a2a_recv: Vec<Vec<SpikeMsg>> = Vec::new();
    bench("exchange: alltoall_into (recycled)", 512, || {
        a2a_send[0].extend_from_slice(&payload);
        comm.alltoall_into(&mut a2a_send, &mut a2a_recv);
        black_box(a2a_recv[0].len());
    });
    bench("exchange: alltoall (fresh alloc)", 512, || {
        a2a_send[0].extend_from_slice(&payload);
        let (recv, _) = comm.alltoall(&mut a2a_send);
        black_box(recv[0].len());
    });
    let mut swap_send = Vec::with_capacity(512);
    let mut swap_recv = Vec::new();
    bench("exchange: local_swap_into", 512, || {
        swap_send.extend_from_slice(&payload);
        comm.local_swap_into(&mut swap_send, &mut swap_recv);
        black_box(swap_recv.len());
    });

    // --- neuron update ------------------------------------------------
    let gids: Vec<u32> = (0..8192).collect();
    let params = LifParams {
        i_e_pa: LifParams::default().i_e_for_rate(8.0),
        ..Default::default()
    };
    let mut block =
        NeuronBlock::build(&gids, 0.1, |_| NeuronKind::Lif(params));
    let syn = vec![0.01f32; 8192];
    let mut spikes = Vec::new();
    bench("update: LIF step (8192 lanes)", 8192, || {
        spikes.clear();
        block.step_native(&syn, &mut spikes);
        black_box(&spikes);
    });
    let mut ianf = NeuronBlock::build(&gids, 0.1, |_| {
        NeuronKind::IgnoreAndFire { interval_steps: 4000 }
    });
    bench("update: ignore-and-fire step (8192)", 8192, || {
        spikes.clear();
        ianf.step_native(&syn, &mut spikes);
        black_box(&spikes);
    });

    // --- virtual cluster throughput -----------------------------------
    println!("\n== macro benchmarks ==\n");
    let machine = MachineProfile::supermuc_ng();
    let spec = models::mam_benchmark(128, 1.0, 1.0).unwrap();
    let w = Workload::derive(&spec, Strategy::Conventional, 128, 48).unwrap();
    let t0 = Instant::now();
    let opts = VcOptions {
        t_model_ms: 1_000.0,
        h_ms: 0.1,
        seed: 654,
        record_cycle_times: false,
    };
    let res = run_cluster(&machine, &w, &opts).unwrap();
    let secs = t0.elapsed().as_secs_f64();
    let rank_cycles = 128.0 * res.s_cycles as f64;
    println!(
        "vcluster: M=128 x {} cycles in {secs:.3} s = {:.2} M rank-cycles/s",
        res.s_cycles,
        rank_cycles / secs / 1e6
    );

    // --- functional engine end-to-end: sequential vs pooled -----------
    let spec = models::mam_benchmark(4, 0.01, 1.0).unwrap();
    for strategy in [Strategy::Conventional, Strategy::StructureAware] {
        for (exec, threads) in [
            (ExecMode::Sequential, 1),
            (ExecMode::Pooled, 1), // must match sequential: no pool at T=1
            (ExecMode::Sequential, 4),
            (ExecMode::Pooled, 4),
        ] {
            let cfg = RunConfig {
                strategy,
                m_ranks: 4,
                threads_per_rank: threads,
                t_model_ms: 100.0,
                seed: 654,
                exec,
                ..RunConfig::default()
            };
            let t0 = Instant::now();
            let res = simulate(&spec, &cfg).unwrap();
            let secs = t0.elapsed().as_secs_f64();
            let neuron_steps =
                spec.total_neurons() as f64 * res.s_cycles as f64;
            println!(
                "engine: {:<16} {:<10} T={threads} {} neurons x {} cycles \
                 in {secs:.3} s = {:.2} M neuron-cycles/s",
                strategy.name(),
                exec.name(),
                spec.total_neurons(),
                res.s_cycles,
                neuron_steps / secs / 1e6
            );
        }
    }
}
