//! Figure-regeneration benchmark: one entry per paper table/figure.
//!
//! Runs every figure harness end-to-end (virtual cluster at shortened
//! model time, full analysis pipeline) and reports wall time per figure
//! plus the figure's headline numbers, so `cargo bench` doubles as the
//! reproduction driver:
//!
//!     cargo bench --bench figures            # quick (1 s model time)
//!     NSIM_BENCH_TMODEL=10000 cargo bench    # full paper protocol

use nsim::figures::{run_figure, FigOptions, ALL_FIGURES};
use std::time::Instant;

fn main() {
    let t_model_ms: f64 = std::env::var("NSIM_BENCH_TMODEL")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(1_000.0);
    let opts = FigOptions { t_model_ms, seed: 654 };
    let out_dir = "results";

    println!(
        "regenerating all {} figures (T_model = {t_model_ms} ms)\n",
        ALL_FIGURES.len()
    );
    let mut total = 0.0;
    for name in ALL_FIGURES {
        let t0 = Instant::now();
        match run_figure(name, &opts) {
            Ok(fig) => {
                let secs = t0.elapsed().as_secs_f64();
                total += secs;
                if let Err(e) = fig.emit(out_dir) {
                    eprintln!("{name}: emit failed: {e:#}");
                }
                println!("[bench] {name:<6} {secs:>8.2} s");
            }
            Err(e) => {
                eprintln!("[bench] {name}: FAILED: {e:#}");
                std::process::exit(1);
            }
        }
        println!();
    }
    println!("[bench] total figure regeneration: {total:.2} s");
}
