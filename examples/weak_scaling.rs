//! End-to-end driver (EXPERIMENTS.md §End-to-end): the paper's Fig 7a
//! weak-scaling protocol exercised across the whole stack.
//!
//! Part 1 — functional engine, real spiking workload: a downscaled
//! MAM-benchmark (areas = M, ignore-and-fire at 2.5 /s, D = 10) simulated
//! for hundreds of thousands of neuron-cycles per point, under both
//! strategies, verifying observational equivalence and reporting measured
//! phase times and communication counts.
//!
//! Part 2 — virtual cluster, paper scale: the same protocol at
//! 130 000 neurons/rank, M = 16..128, T = 10 s biological time,
//! reproducing the shape of Fig 7a (who wins, by how much, where it
//! grows).
//!
//!     cargo run --release --example weak_scaling [-- --t-model 10000]

use nsim::config::{RunConfig, Strategy};
use nsim::engine::simulate;
use nsim::models;
use nsim::util::cli::Args;
use nsim::util::tablefmt::{fnum, Table};
use nsim::util::timers::Phase;
use nsim::vcluster::{run_cluster, MachineProfile, VcOptions, Workload};

fn main() -> anyhow::Result<()> {
    let args = Args::from_env()?;
    let t_model_vc = args.f64_or("t-model", 2_000.0)?;
    let t_model_fn = args.f64_or("t-model-functional", 200.0)?;
    args.finish()?;

    // ---------- Part 1: functional engine (real spikes) ----------
    println!("== Part 1: functional engine, downscaled MAM-benchmark ==");
    let mut table = Table::new(&[
        "M", "strategy", "neurons", "spikes", "deliver", "update",
        "collocate", "sync", "data", "a2a-calls",
    ]);
    for m in [1usize, 2, 4, 8] {
        let spec = models::mam_benchmark(m.max(2), 0.004, 1.0)?;
        let mut trains = Vec::new();
        for strategy in [Strategy::Conventional, Strategy::StructureAware] {
            let cfg = RunConfig {
                strategy,
                m_ranks: m,
                threads_per_rank: 2,
                t_model_ms: t_model_fn,
                seed: 654,
                record_spikes: true,
                ..RunConfig::default()
            };
            let res = simulate(&spec, &cfg)?;
            table.row(vec![
                m.to_string(),
                strategy.name().into(),
                spec.total_neurons().to_string(),
                res.n_spikes().to_string(),
                fnum(res.mean_times.get(Phase::Deliver)),
                fnum(res.mean_times.get(Phase::Update)),
                fnum(res.mean_times.get(Phase::Collocate)),
                fnum(res.mean_times.get(Phase::Synchronize)),
                fnum(res.mean_times.get(Phase::DataExchange)),
                res.comm_stats.0.to_string(),
            ]);
            trains.push(res.spikes);
        }
        assert_eq!(
            trains[0], trains[1],
            "equivalence violated at M={m}"
        );
    }
    println!("{}", table.render());
    println!("equivalence: all M produced identical spike trains.\n");

    // ---------- Part 2: virtual cluster at paper scale ----------
    println!(
        "== Part 2: virtual cluster (SuperMUC-NG profile), paper scale, \
         T_model = {} ms ==",
        t_model_vc
    );
    let machine = MachineProfile::supermuc_ng();
    let mut table = Table::new(&[
        "M", "strategy", "RTF", "deliver", "update", "collocate", "sync",
        "data",
    ]);
    let mut headline = Vec::new();
    for &m in &[16usize, 32, 64, 128] {
        let spec = models::mam_benchmark(m, 1.0, 1.0)?;
        for strategy in [Strategy::Conventional, Strategy::StructureAware] {
            let w = Workload::derive(&spec, strategy, m, machine.t_m)?;
            let res = run_cluster(
                &machine,
                &w,
                &VcOptions {
                    t_model_ms: t_model_vc,
                    h_ms: spec.h_ms,
                    seed: 654,
                    record_cycle_times: false,
                },
            )?;
            let t_s = t_model_vc / 1000.0;
            table.row(vec![
                m.to_string(),
                strategy.name().into(),
                fnum(res.rtf()),
                fnum(res.mean_times.get(Phase::Deliver) / t_s),
                fnum(res.mean_times.get(Phase::Update) / t_s),
                fnum(res.mean_times.get(Phase::Collocate) / t_s),
                fnum(res.mean_times.get(Phase::Synchronize) / t_s),
                fnum(res.mean_times.get(Phase::DataExchange) / t_s),
            ]);
            headline.push((m, strategy, res.rtf()));
        }
    }
    println!("{}", table.render());
    let rtf = |m: usize, s: Strategy| {
        headline
            .iter()
            .find(|(hm, hs, _)| *hm == m && *hs == s)
            .unwrap()
            .2
    };
    println!(
        "headline: conventional RTF {:.1} -> {:.1} (M=16 -> 128), \
         structure-aware {:.1} -> {:.1}; reduction at M=128: {:.0}%\n\
         (paper: 9.4 -> 22.7 vs 8.5 -> 15.7; reduction ~30%)",
        rtf(16, Strategy::Conventional),
        rtf(128, Strategy::Conventional),
        rtf(16, Strategy::StructureAware),
        rtf(128, Strategy::StructureAware),
        100.0
            * (1.0
                - rtf(128, Strategy::StructureAware)
                    / rtf(128, Strategy::Conventional))
    );
    Ok(())
}
