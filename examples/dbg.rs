use nsim::config::{RunConfig, Strategy};
use nsim::engine::simulate;
use nsim::models;
fn main() {
    let spec = models::sanity_net(300, 4).unwrap();
    for seed in [12u64, 91856] {
        let cfg = RunConfig { strategy: Strategy::Conventional, m_ranks: 2, threads_per_rank: 2,
            t_model_ms: 200.0, seed, record_spikes: true, ..Default::default() };
        let res = simulate(&spec, &cfg).unwrap();
        println!("seed {}: {} spikes, rate {:.3}", seed, res.n_spikes(), res.mean_rate_hz(1200));
    }
    // how strong is the drive vs weights?
    use nsim::network::spec::LifParams;
    let p = LifParams { i_e_pa: LifParams::default().i_e_for_rate(8.0), ..Default::default() };
    println!("drive/step = {:.5} mV, w = 0.25 mV, k_intra={} k_inter={}", p.drive(0.1), spec.k_intra, spec.k_inter);
}
