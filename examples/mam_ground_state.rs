//! The real-world model: the 32-area macaque visual cortex model (MAM)
//! in its ground state.
//!
//! Functionally simulates a downscaled MAM (LIF neurons, heterogeneous
//! area sizes and drives) under all three strategies — conventional,
//! intermediate, structure-aware — optionally pushing the update phase
//! through the AOT-compiled XLA artifact (`--update-path xla`), then
//! reproduces the paper's Fig 9 comparison at full scale on both machine
//! profiles with the virtual cluster.
//!
//!     cargo run --release --example mam_ground_state
//!     cargo run --release --example mam_ground_state -- --update-path xla

use nsim::config::{RunConfig, Strategy, UpdatePath};
use nsim::engine::simulate;
use nsim::models;
use nsim::util::cli::Args;
use nsim::util::tablefmt::{fnum, Table};
use nsim::util::timers::Phase;
use nsim::vcluster::{run_cluster, MachineProfile, VcOptions, Workload};

fn main() -> anyhow::Result<()> {
    let args = Args::from_env()?;
    let scale = args.f64_or("scale", 0.002)?;
    let t_model = args.f64_or("t-model", 200.0)?;
    let update_path = match args.str_or("update-path", "native").as_str() {
        "xla" => UpdatePath::Xla,
        _ => UpdatePath::Native,
    };
    args.finish()?;

    let spec = models::mam(scale, 1.0)?;
    println!(
        "MAM ground state: {} areas, {} neurons (scale {}), D = {}",
        spec.n_areas(),
        spec.total_neurons(),
        scale,
        spec.delay_ratio()
    );

    // ---------- functional simulation, M=8 ranks (4 areas each) --------
    let mut table = Table::new(&[
        "strategy", "spikes", "rate/s", "deliver", "update", "collocate",
        "sync", "data",
    ]);
    let mut rates = Vec::new();
    for strategy in [
        Strategy::Conventional,
        Strategy::Intermediate,
        Strategy::StructureAware,
    ] {
        let cfg = RunConfig {
            strategy,
            m_ranks: 8,
            threads_per_rank: 2,
            t_model_ms: t_model,
            seed: 12,
            update_path,
            record_spikes: true,
            record_cycle_times: false,
        };
        let res = simulate(&spec, &cfg)?;
        let rate = res.mean_rate_hz(spec.total_neurons() as usize);
        table.row(vec![
            strategy.name().into(),
            res.n_spikes().to_string(),
            fnum(rate),
            fnum(res.mean_times.get(Phase::Deliver)),
            fnum(res.mean_times.get(Phase::Update)),
            fnum(res.mean_times.get(Phase::Collocate)),
            fnum(res.mean_times.get(Phase::Synchronize)),
            fnum(res.mean_times.get(Phase::DataExchange)),
        ]);
        rates.push(rate);
    }
    println!("{}", table.render());
    // the MAM draws random (non-binary-fraction) weights; spike trains
    // may differ in float ulps across strategies, rates must agree
    let spread = rates
        .iter()
        .cloned()
        .fold(f64::MIN, f64::max)
        - rates.iter().cloned().fold(f64::MAX, f64::min);
    assert!(
        spread < 0.05 * rates[0].max(0.1),
        "strategy rate spread too large: {rates:?}"
    );
    println!("rates agree across strategies: {rates:?}\n");

    // ---------- paper scale (Fig 9): both machines, three strategies ---
    println!("== Fig 9 protocol at paper scale (virtual cluster) ==");
    let spec_full = models::mam(1.0, 1.0)?;
    let mut table = Table::new(&[
        "machine/strategy",
        "RTF",
        "deliver",
        "update",
        "collocate",
        "sync",
        "data",
    ]);
    for machine in [MachineProfile::supermuc_ng(), MachineProfile::jureca_dc()]
    {
        for strategy in [
            Strategy::Conventional,
            Strategy::Intermediate,
            Strategy::StructureAware,
        ] {
            let w =
                Workload::derive(&spec_full, strategy, 32, machine.t_m)?;
            let res = run_cluster(
                &machine,
                &w,
                &VcOptions {
                    t_model_ms: 2_000.0,
                    h_ms: spec_full.h_ms,
                    seed: 654,
                    record_cycle_times: false,
                },
            )?;
            let t_s = 2.0;
            table.row(vec![
                format!("{}/{}", machine.name, strategy.name()),
                fnum(res.rtf()),
                fnum(res.mean_times.get(Phase::Deliver) / t_s),
                fnum(res.mean_times.get(Phase::Update) / t_s),
                fnum(res.mean_times.get(Phase::Collocate) / t_s),
                fnum(res.mean_times.get(Phase::Synchronize) / t_s),
                fnum(res.mean_times.get(Phase::DataExchange) / t_s),
            ]);
        }
    }
    println!("{}", table.render());
    Ok(())
}
