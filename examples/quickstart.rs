//! Quickstart: build a small multi-area network, run it under the
//! conventional and the structure-aware strategy, and verify that the
//! two produce *identical* spike trains while communicating globally
//! 10x less often.
//!
//!     cargo run --release --example quickstart

use nsim::config::{RunConfig, Strategy};
use nsim::engine::simulate;
use nsim::models;
use nsim::util::timers::Phase;

fn main() -> anyhow::Result<()> {
    // a 4-area LIF network, 300 neurons per area, intra-area delays
    // >= 0.1 ms, inter-area delays >= 1.0 ms  =>  delay ratio D = 10
    let spec = models::sanity_net(300, 4)?;
    println!(
        "model: {} | {} neurons | {} areas | D = {}",
        spec.name,
        spec.total_neurons(),
        spec.n_areas(),
        spec.delay_ratio()
    );

    let mut spike_trains = Vec::new();
    for strategy in [Strategy::Conventional, Strategy::StructureAware] {
        let cfg = RunConfig {
            strategy,
            m_ranks: 4,
            threads_per_rank: 2,
            t_model_ms: 500.0,
            seed: 12,
            record_spikes: true,
            ..RunConfig::default()
        };
        let res = simulate(&spec, &cfg)?;
        println!(
            "\n{}: {} spikes, {:.2} spikes/s/neuron, \
             {} global exchanges, {} local swaps",
            strategy.name(),
            res.n_spikes(),
            res.mean_rate_hz(spec.total_neurons() as usize),
            res.comm_stats.0,
            res.comm_stats.1,
        );
        for p in Phase::ALL {
            println!("  {:<13} {:.4} s", p.name(), res.mean_times.get(p));
        }
        spike_trains.push(res.spikes);
    }

    assert_eq!(
        spike_trains[0], spike_trains[1],
        "strategies must be observationally equivalent"
    );
    println!(
        "\nOK: identical spike trains ({} events) — the structure-aware \
         strategy changed the communication schedule, not the dynamics.",
        spike_trains[0].len()
    );
    Ok(())
}
